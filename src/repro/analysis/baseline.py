"""Finding allowlist: known violations are explicit, new ones fail.

A baseline file is plain text, one :meth:`Finding.key` per line
(``rule::backend::program::primitive``), ``#`` comments and blank lines
ignored. The repo's serving programs currently lint clean, so no baseline
ships; the machinery exists so a future *deliberate* violation (say, a
transitional scatter while a kernel lands) is recorded in-tree and
reviewed, instead of the rule being switched off.

``python -m repro.analysis.lint --write-baseline FILE`` snapshots the
current findings; ``--baseline FILE`` applies one.
"""
from __future__ import annotations

import os
from typing import Iterable

from repro.analysis.rules import Finding

__all__ = ["load_baseline", "save_baseline", "split_baselined",
           "stale_keys"]


def load_baseline(path: str | os.PathLike | None) -> frozenset[str]:
    """Keys from a baseline file; empty set for ``None`` / missing file."""
    if path is None:
        return frozenset()
    if not os.path.exists(path):
        raise FileNotFoundError(f"baseline file {path!r} does not exist "
                                f"(write one with --write-baseline)")
    keys = set()
    with open(path) as f:
        for line in f:
            line = line.split("#", 1)[0].strip()
            if line:
                keys.add(line)
    return frozenset(keys)


def save_baseline(path: str | os.PathLike,
                  findings: Iterable[Finding]) -> int:
    """Write the de-duplicated keys of ``findings``; returns the count."""
    keys = sorted({f.key() for f in findings})
    with open(path, "w") as f:
        f.write("# tracelint baseline — one Finding.key per line\n"
                "# (rule::backend::program::primitive); delete a line to "
                "re-arm the rule\n")
        for k in keys:
            f.write(k + "\n")
    return len(keys)


def stale_keys(baseline: Iterable[str],
               findings: Iterable[Finding]) -> list[str]:
    """Baseline entries that no current finding matches.

    A stale entry is dead weight with teeth: the violation it allowed
    was fixed, but the line would silently re-allow a *recurrence*.
    ``lint --prune-baseline`` reports these (and with ``--write-baseline``
    removes them) so the allowlist can't rot."""
    live = {f.key() for f in findings}
    return sorted(k for k in frozenset(baseline) if k not in live)


def split_baselined(findings: Iterable[Finding],
                    baseline: frozenset[str] | Iterable[str]
                    ) -> tuple[list[Finding], list[Finding]]:
    """(new, suppressed) partition of ``findings`` against ``baseline``."""
    baseline = frozenset(baseline)
    new, suppressed = [], []
    for f in findings:
        (suppressed if f.key() in baseline else new).append(f)
    return new, suppressed
