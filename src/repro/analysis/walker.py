"""Structural jaxpr walker: every equation, at every nesting depth.

The string asserts this package replaces (``"pure_callback" not in
str(jaxpr)``) matched the *printed* jaxpr — they could false-positive on a
variable name, could not say which equation violated, and silently
depended on the printer recursing. This walker recurses for real: any
``ClosedJaxpr`` / ``Jaxpr`` found in an equation's params (``scan`` and
``while`` bodies, ``cond`` branches, ``pjit``/``remat``/``custom_*`` call
jaxprs, ``pallas_call`` kernel jaxprs, ...) is entered, and every visited
equation comes back as an :class:`EqnSite` carrying

* ``path`` — the equation's address, e.g.
  ``"12:scan/jaxpr/3:pjit/jaxpr/0:scatter"`` (index ``:`` primitive at
  each level), printable in a finding;
* ``in_loop`` — whether any enclosing equation is a ``scan``/``while``
  body (the level-loop invariants key on this);
* ``scopes`` — the union of ``jax.named_scope`` components on the
  equation itself and on every enclosing call equation (sub-jaxpr
  equations carry only their local name stack, so scope membership must
  be inherited down the walk).

Primitive-name sets used by several rules live here so rules and tests
share one spelling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

from jax import core

__all__ = ["EqnSite", "iter_eqns", "subjaxprs", "CALLBACK_PRIMS",
           "SCATTER_PRIMS", "LOOP_PRIMS", "CALL_PRIMS"]

# host-callback family: anything that escapes the device program
CALLBACK_PRIMS = frozenset({"pure_callback", "io_callback",
                            "debug_callback"})
# scatter family (jax spells variants with a hyphen)
SCATTER_PRIMS = frozenset({"scatter", "scatter-add", "scatter-sub",
                           "scatter-mul", "scatter-min", "scatter-max",
                           "scatter-apply"})
# primitives whose sub-jaxprs execute repeatedly (loop bodies)
LOOP_PRIMS = frozenset({"scan", "while"})
# call-like primitives (enter exactly once; not loops)
CALL_PRIMS = frozenset({"pjit", "cond", "remat2", "custom_jvp_call",
                        "custom_vjp_call", "custom_vjp_call_jaxpr",
                        "pallas_call", "closed_call", "core_call",
                        "xla_call"})


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """One visited equation with its structural context."""
    eqn: Any                      # jax.core.JaxprEqn
    path: str                     # "12:scan/jaxpr/0:scatter"
    in_loop: bool                 # inside any scan/while body
    scopes: frozenset[str]        # inherited named_scope components

    @property
    def primitive(self) -> str:
        return self.eqn.primitive.name


def subjaxprs(eqn) -> Iterator[tuple[str, core.Jaxpr]]:
    """(param-key, jaxpr) for every sub-jaxpr in ``eqn.params``.

    ``while`` keeps its two jaxprs under ``cond_jaxpr``/``body_jaxpr``;
    ``cond`` keeps a tuple under ``branches``; most call-likes keep one
    under ``jaxpr``/``call_jaxpr``. The custom-derivative wrappers are
    covered the same way — ``custom_jvp_call`` carries its primal under
    ``call_jaxpr`` and ``custom_vjp_call``/``custom_vjp_call_jaxpr``
    under ``fun_jaxpr``, so a callback or scatter cannot hide behind a
    ``jax.custom_jvp``/``jax.custom_vjp`` decorator (positive controls in
    tests/test_analysis.py). Rather than enumerate primitives, look at
    the values: anything that *is* a jaxpr gets walked.
    """
    for key, val in eqn.params.items():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for i, v in enumerate(vals):
            label = key if len(vals) == 1 else f"{key}[{i}]"
            if isinstance(v, core.ClosedJaxpr):
                yield label, v.jaxpr
            elif isinstance(v, core.Jaxpr):
                yield label, v


def _eqn_scopes(eqn) -> frozenset[str]:
    stack = getattr(eqn.source_info, "name_stack", None)
    s = str(stack) if stack is not None else ""
    return frozenset(p for p in s.split("/") if p)


def iter_eqns(jaxpr, *, _path: str = "", _in_loop: bool = False,
              _scopes: frozenset[str] = frozenset()) -> Iterator[EqnSite]:
    """Yield an :class:`EqnSite` for every equation, recursing into every
    sub-jaxpr. Accepts a ``ClosedJaxpr`` or a ``Jaxpr``."""
    if isinstance(jaxpr, core.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for i, eqn in enumerate(jaxpr.eqns):
        name = eqn.primitive.name
        here = f"{_path}{i}:{name}"
        scopes = _scopes | _eqn_scopes(eqn)
        yield EqnSite(eqn=eqn, path=here, in_loop=_in_loop, scopes=scopes)
        loop = _in_loop or name in LOOP_PRIMS
        for label, sub in subjaxprs(eqn):
            # a while's cond jaxpr runs per iteration too — both count
            yield from iter_eqns(sub, _path=f"{here}/{label}/",
                                 _in_loop=loop, _scopes=scopes)
