"""Tracelint: static analysis of the serving programs' jaxprs and HLO.

The paper's premise is that the computation's *structure* — the
transitive DAG, its execution order — is analyzable ahead of time; this
package is the software twin of that idea. It walks the serving programs
(prefill / decode / paged decode / the DevicePlan forest, per registered
backend) structurally, recursing into ``scan``/``while``/``cond``/
``pjit``/``pallas_call`` sub-jaxprs, and enforces the invariants the
perf story rests on: no host callbacks, gather-only level loops, static
shapes, real KV-cache donation, f32-pure quantize subgraphs, no silent
replication under a mesh. See docs/ANALYSIS.md for the rule catalog.

Three entry points:

* :func:`assert_clean` — the pytest helper replacing the old
  ``"pure_callback" not in str(jaxpr)`` string asserts: trace, lint,
  raise with the offending primitive and its equation path.
* :func:`find_violations` — same, returning the findings (for tests that
  assert a violation *is* present).
* ``python -m repro.analysis.lint`` — the CI gate: every registered
  backend's programs, all rules, allowlist baseline, JSON report.
"""
from __future__ import annotations

import jax
from jax import core

from repro.analysis.baseline import (load_baseline, save_baseline,
                                     split_baselined, stale_keys)
from repro.analysis.costcheck import (CostMetrics, check_budgets,
                                      crosscheck_costmodel, jaxpr_cost,
                                      load_budgets, plan_cost,
                                      program_metrics)
from repro.analysis.planlint import (PlanVerificationError, gate_params,
                                     gate_plan, lint_plans,
                                     list_plan_rules, register_plan_rule,
                                     unregister_plan_rule,
                                     verify_bundle_file,
                                     verify_device_plan, verify_manifest,
                                     verify_plan)
from repro.analysis.programs import (PROGRAM_RULES, build_programs,
                                     lint_backend)
from repro.analysis.rules import (Finding, LintProgram, Rule, get_rule,
                                  list_rules, register_rule, run_rules,
                                  unregister_rule)
from repro.analysis.walker import (CALLBACK_PRIMS, LOOP_PRIMS,
                                   SCATTER_PRIMS, EqnSite, iter_eqns)

__all__ = ["Finding", "LintProgram", "Rule", "EqnSite", "iter_eqns",
           "register_rule", "unregister_rule", "get_rule", "list_rules",
           "run_rules", "build_programs", "lint_backend", "PROGRAM_RULES",
           "load_baseline", "save_baseline", "split_baselined",
           "stale_keys",
           "find_violations", "assert_clean", "DEFAULT_RULES",
           "CALLBACK_PRIMS",
           "SCATTER_PRIMS", "LOOP_PRIMS",
           # plan-IR verifier (planlint.py)
           "PlanVerificationError", "verify_plan", "verify_device_plan",
           "verify_manifest", "verify_bundle_file", "gate_plan",
           "gate_params", "register_plan_rule", "unregister_plan_rule",
           "list_plan_rules", "lint_plans",
           # static cost certifier (costcheck.py)
           "CostMetrics", "jaxpr_cost", "plan_cost", "program_metrics",
           "crosscheck_costmodel", "load_budgets", "check_budgets"]

# the structural rules assert_clean runs when the caller names none: the
# invariant the retired string asserts guarded plus its schedule sibling
# (both jaxpr-level and true of every serving program; gather-only-levels
# is NOT here — model programs legally scatter KV-cache writes inside the
# block scan, so it only guards forest programs and must be requested:
# rules=(*DEFAULT_RULES, "gather-only-levels"))
DEFAULT_RULES = ("no-host-callback", "static-shapes")


def find_violations(fn, *args, rules: tuple[str, ...] = DEFAULT_RULES,
                    name: str = "program", backend: str | None = None,
                    quantize_scopes: tuple[str, ...] = ("quantize_kv",),
                    **program_kw) -> list[Finding]:
    """Trace ``fn(*args)`` (or take a ready ``ClosedJaxpr``) and run the
    named jaxpr-level rules; returns the findings.

    ``program_kw`` forwards extra :class:`LintProgram` evidence
    (``lowered_text=``, ``donate_expect=``, ``mesh=``, ``arrays=``) for
    rules that need more than the jaxpr.
    """
    if isinstance(fn, core.ClosedJaxpr):
        if args:
            raise TypeError("passing args with an already-traced "
                            "ClosedJaxpr makes no sense")
        jaxpr = fn
    else:
        jaxpr = jax.make_jaxpr(fn)(*args)
    prog = LintProgram(name=name, backend=backend, rules=tuple(rules),
                      jaxpr=jaxpr, quantize_scopes=quantize_scopes,
                      **program_kw)
    return run_rules(prog)


def assert_clean(fn, *args, rules: tuple[str, ...] = DEFAULT_RULES,
                 baseline: frozenset[str] | tuple[str, ...] = (),
                 **kw) -> None:
    """Assert ``fn(*args)``'s program violates none of ``rules``.

    The drop-in replacement for the old string asserts: on violation the
    AssertionError names every offending primitive and its equation path
    inside the (possibly nested) jaxpr — not just "the string appeared".
    """
    findings = find_violations(fn, *args, rules=rules, **kw)
    new, _ = split_baselined(findings, frozenset(baseline))
    if new:
        lines = "\n  ".join(f.format() for f in new)
        raise AssertionError(
            f"tracelint: {len(new)} violation(s) of "
            f"{', '.join(rules)}:\n  {lines}")
