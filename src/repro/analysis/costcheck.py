"""Static cost certifier: budget every serving program without a timer.

The paper's ahead-of-time-analyzability premise cuts both ways: if the
execution schedule is a pure function of the input signature, then so is
its *cost*. This module derives per-program cost metrics from the traced
jaxpr (scan-trip-weighted gather counts and bytes, scatter-in-loop
counts, peak live-buffer footprint, KV-pool read traffic via a
view-tracking walk) and from the plan IR itself (level/edge/gather
counts), cross-checks the plan-derived op counts against the analytical
cost model (``core/costmodel.py`` / ``core/patterns.py`` — the two must
be the *same* arithmetic or the DSE story models a machine the kernels
don't run), and enforces declarative budgets from
``analysis/budgets.json``. A budget violation is an ordinary
:class:`~repro.analysis.rules.Finding` (rule ``cost-budget``), so it
baselines, reports and fails CI exactly like a tracelint finding — a
perf gate that needs no timer and cannot flake.

The two headline budgets:

* ``live-page-decode`` — the Pallas paged-attention decode's KV-pool
  read traffic is O(live pages), not O(max_len): the certifier traces
  the program at ``max_len`` and ``2 * max_len`` and the bytes gathered
  *from the pool* (taint-tracked from the donated pool argument range)
  must not grow. The oracle paged decode, which gathers the whole page
  table each step, fails this budget by construction — that asymmetry
  is the regression test for the fast path.
* ``swap-trace-count`` — a pad-aligned hot swap re-traces the decode
  jit zero times (``decode_jit_traces == 1`` across the swap); a
  drifted swap demonstrably fails it.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Iterator

import numpy as np

from repro.analysis.rules import Finding
from repro.analysis.walker import LOOP_PRIMS, SCATTER_PRIMS, subjaxprs

__all__ = ["CostMetrics", "jaxpr_cost", "plan_cost",
           "crosscheck_costmodel", "load_budgets", "program_metrics",
           "growth_ratio", "swap_trace_count", "check_budgets",
           "DEFAULT_BUDGETS"]

DEFAULT_BUDGETS = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                               "budgets.json")
_BUDGET_FORMAT = 1

# primitives that read memory through an index vector
GATHER_PRIMS = frozenset({"gather", "dynamic_slice"})
# single-operand structural transforms: the output is still "the same
# buffer" for the purposes of pool-read attribution (view tracking)
VIEW_PRIMS = frozenset({"reshape", "transpose", "convert_element_type",
                        "squeeze", "broadcast_in_dim", "slice", "rev",
                        "copy", "dynamic_update_slice", "copy_p",
                        *SCATTER_PRIMS})


@dataclasses.dataclass
class CostMetrics:
    """Signature-determined costs of one traced program.

    ``*_dynamic`` / byte fields are **scan-weighted**: an equation
    inside a ``lax.scan`` of length L counts L times (nested scans
    multiply), so the numbers are per-call costs, not per-trace counts.
    ``pool_*`` fields only fill when the caller names a pool argument
    range; ``*_unguarded`` excludes equations inside ``lax.cond``
    branches (runtime-skippable work — the live-page kernel's dead-page
    loads live there).
    """
    eqns: int = 0
    eqns_dynamic: float = 0.0
    gathers: int = 0
    gathers_dynamic: float = 0.0
    gather_bytes: float = 0.0
    gather_bytes_unguarded: float = 0.0
    pool_gathers: int = 0
    pool_gather_bytes: float = 0.0
    pool_gather_bytes_unguarded: float = 0.0
    scatters: int = 0
    scatter_in_loop: int = 0
    scatter_in_loop_dynamic: float = 0.0
    while_loops: int = 0
    peak_live_bytes: int = 0

    def to_json(self) -> dict[str, float]:
        return {k: (round(v, 1) if isinstance(v, float) else v)
                for k, v in dataclasses.asdict(self).items()}


def _aval_bytes(v: Any) -> int:
    aval = getattr(v, "aval", None)
    shape = getattr(aval, "shape", None)
    dtype = getattr(aval, "dtype", None)
    if shape is None or dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * int(
        np.dtype(dtype).itemsize)


def _inner(jaxpr: Any) -> Any:
    return jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr


def _walk(jaxpr: Any, view_in: list[bool], weight: float, in_loop: bool,
          guarded: bool, acc: CostMetrics) -> list[bool]:
    """Accumulate costs; returns which outvars are pool views."""
    from jax import core
    j = _inner(jaxpr)
    views = {v for v, t in zip(j.invars, view_in) if t}
    for eqn in j.eqns:
        name = eqn.primitive.name
        inv = [(not isinstance(v, core.Literal)) and v in views
               for v in eqn.invars]
        acc.eqns += 1
        acc.eqns_dynamic += weight
        if name in GATHER_PRIMS:
            nbytes = sum(_aval_bytes(v) for v in eqn.outvars)
            acc.gathers += 1
            acc.gathers_dynamic += weight
            acc.gather_bytes += weight * nbytes
            if not guarded:
                acc.gather_bytes_unguarded += weight * nbytes
            if inv and inv[0]:
                acc.pool_gathers += 1
                acc.pool_gather_bytes += weight * nbytes
                if not guarded:
                    acc.pool_gather_bytes_unguarded += weight * nbytes
        if name in SCATTER_PRIMS:
            acc.scatters += 1
            if in_loop:
                acc.scatter_in_loop += 1
                acc.scatter_in_loop_dynamic += weight
        if name == "while":
            acc.while_loops += 1
        sub_w = weight * (int(eqn.params.get("length", 1))
                          if name == "scan" else 1)
        sub_guard = guarded or name == "cond"
        sub_loop = in_loop or name in LOOP_PRIMS
        entered = False
        for _label, sub in subjaxprs(eqn):
            entered = True
            sj = _inner(sub)
            n = len(sj.invars)
            if name == "cond":
                sub_view = inv[1:1 + n]        # invars[0] is the index
            else:                              # pjit/scan/...: positional
                sub_view = inv[:n]
            sub_view = sub_view + [False] * (n - len(sub_view))
            out_view = _walk(sub, sub_view, sub_w, sub_loop, sub_guard,
                             acc)
            for v, t in zip(eqn.outvars, out_view):
                if t:
                    views.add(v)
        if not entered and name in VIEW_PRIMS and inv and inv[0]:
            for v in eqn.outvars:
                views.add(v)
    return [(not isinstance(v, core.Literal)) and v in views
            for v in j.outvars]


def _peak_live_bytes(jaxpr: Any) -> int:
    """Top-level liveness scan: peak sum of live aval bytes.

    Inputs are live from the start, every var dies after its last use
    (outputs at the end) — a coarse upper-structure metric, but it is
    signature-determined and moves when someone materialises a second
    KV cache."""
    from jax import core
    j = _inner(jaxpr)
    last_use: dict[Any, int] = {}
    n = len(j.eqns)
    for i, eqn in enumerate(j.eqns):
        for v in eqn.invars:
            if not isinstance(v, core.Literal):
                last_use[v] = i
    for v in j.outvars:
        if not isinstance(v, core.Literal):
            last_use[v] = n
    live = {v: _aval_bytes(v) for v in j.invars}
    peak = cur = sum(live.values())
    for i, eqn in enumerate(j.eqns):
        for v in eqn.outvars:
            if v not in live:
                live[v] = _aval_bytes(v)
                cur += live[v]
        peak = max(peak, cur)
        for v in list(live):
            if last_use.get(v, n) <= i:
                cur -= live.pop(v)
    return int(peak)


def jaxpr_cost(jaxpr: Any, *,
               pool_range: tuple[int, int] | None = None) -> CostMetrics:
    """Derive :class:`CostMetrics` from a (Closed)Jaxpr.

    ``pool_range`` names the ``[start, stop)`` flattened-invar range of
    the KV pool (the same range ``LintProgram.donate_expect`` carries);
    gathers whose operand is a *view* of those invars fill the
    ``pool_*`` fields.
    """
    j = _inner(jaxpr)
    n_in = len(j.invars)
    if pool_range is None:
        view_in = [False] * n_in
    else:
        start, stop = pool_range
        view_in = [start <= i < stop for i in range(n_in)]
    acc = CostMetrics()
    _walk(jaxpr, view_in, 1.0, False, False, acc)
    acc.peak_live_bytes = _peak_live_bytes(jaxpr)
    return acc


def program_metrics(prog: Any) -> CostMetrics:
    """Metrics for one :class:`~repro.analysis.rules.LintProgram`; the
    pool range comes from its ``donate_expect`` when present."""
    pool = None
    for label, (start, stop) in (prog.donate_expect or {}).items():
        pool = (start, stop)
    return jaxpr_cost(prog.jaxpr, pool_range=pool)


# ---------------------------------------------------------------------------
# Plan-IR costs + cost-model cross-check
# ---------------------------------------------------------------------------

def plan_cost(plan: Any) -> dict[str, int]:
    """Per-call costs read straight off the plan IR (host side)."""
    t, size = int(plan.t), 1 << int(plan.t)
    j = plan.k // plan.t
    r = j * size
    s, n = int(plan.bits), int(plan.n)
    step_edges = sum(int(np.asarray(st.tile).size) for st in plan.steps)
    direct_adds = int(np.asarray(plan.direct_bits).sum())
    return {
        "levels": len(plan.steps),
        "psum_rows": r,
        "step_edges": step_edges,
        "direct_lanes": int(np.asarray(plan.direct_tile).size),
        "direct_adds": direct_adds,
        "ppe_adds": step_edges + direct_adds,
        # each level is two whole-table gathers (psum + activation)
        "level_gather_rows": 2 * t * r,
        "ape_gather_rows": s * n * j,
    }


def crosscheck_costmodel(plan: Any, *, backend: str | None = None,
                         name: str = "plan") -> list[Finding]:
    """The plan IR and the analytical cost model must count the same ops.

    ``core/patterns.py``'s :func:`tile_stats` (which feeds
    ``core/costmodel.py``'s TransitiveArrayModel via the scoreboard) and
    the executable schedule are two derivations of the same quantities:

    * ``ppe_ops`` (prefix-chain adds) == schedule step edges + direct
      subset-sum adds;
    * ``ape_ops`` (output accumulations) == nonzero TransRows
      == S*N*J - zero rows.

    Disagreement means the DSE/roofline story budgets a machine the
    kernels don't run — an error finding, not a warning.
    """
    from repro.core.patterns import tile_stats
    ts = tile_stats(plan.si)
    pc = plan_cost(plan)
    out: list[Finding] = []
    ppe_model = int(np.asarray(ts.ppe_ops).sum())
    if ppe_model != pc["ppe_adds"]:
        out.append(Finding(
            rule="cost-model-agreement", severity="error", program=name,
            backend=backend, path="ppe_ops", primitive="ppe_ops",
            message=f"cost model counts {ppe_model} PPE adds but the "
            f"schedule executes {pc['ppe_adds']} ({pc['step_edges']} "
            f"step edges + {pc['direct_adds']} direct adds) — the "
            f"analytical model and the plan IR have diverged"))
        return out
    ape_model = int(np.asarray(ts.ape_ops).sum())
    s, n = int(plan.bits), int(plan.n)
    j = plan.k // plan.t
    zr = int(np.asarray(ts.zr).sum())
    if ape_model != s * n * j - zr or ape_model > s * n * j:
        out.append(Finding(
            rule="cost-model-agreement", severity="error", program=name,
            backend=backend, path="ape_ops", primitive="ape_ops",
            message=f"cost model counts {ape_model} APE accumulations "
            f"but the plan implies {s * n * j - zr} nonzero TransRows "
            f"(S*N*J={s * n * j}, zero rows={zr})"))
    return out


# ---------------------------------------------------------------------------
# Declarative budgets
# ---------------------------------------------------------------------------

def load_budgets(path: str | os.PathLike | None = None) -> dict[str, Any]:
    """Load and validate the budgets file (default: the in-tree one)."""
    path = DEFAULT_BUDGETS if path is None else path
    with open(path) as f:
        data = json.load(f)
    if not isinstance(data, dict) or data.get("format") != _BUDGET_FORMAT:
        raise ValueError(f"{path}: not a format-{_BUDGET_FORMAT} budgets "
                         f"file (got format={data.get('format')!r})")
    for i, b in enumerate(data.get("budgets", [])):
        missing = [k for k in ("name", "program", "metric", "max")
                   if k not in b]
        if missing:
            raise ValueError(f"{path}: budgets[{i}] is missing {missing}")
    return data


def growth_ratio(backend: str, program: str, metric: str, *,
                 mesh: Any = None, arch: str = "smollm-135m",
                 scales: tuple[int, int] = (16, 32)
                 ) -> tuple[float, dict[str, float]]:
    """Trace ``program`` at two ``max_len`` scales; ratio of ``metric``.

    The +1 regularisation keeps a 0 -> 0 metric (the kernel path's pool
    reads) at ratio 1.0 instead of 0/0.
    """
    from repro.analysis.programs import build_programs
    values = {}
    for ml in scales:
        progs = {p.name: p for p in build_programs(
            backend, mesh=mesh, arch=arch, max_len=ml)}
        if program not in progs:
            raise KeyError(f"backend {backend!r} builds no {program!r} "
                           f"program")
        m = program_metrics(progs[program])
        values[f"max_len={ml}"] = float(getattr(m, metric))
    lo, hi = (values[f"max_len={s}"] for s in scales)
    return (hi + 1.0) / (lo + 1.0), values


def _map_device_plans(tree: Any, fn: Callable[[Any], Any]) -> Any:
    from repro.core.engine import DevicePlan
    if isinstance(tree, DevicePlan):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: _map_device_plans(v, fn) for k, v in tree.items()}
    if isinstance(tree, list):
        return [_map_device_plans(v, fn) for v in tree]
    if isinstance(tree, tuple):
        return tuple(_map_device_plans(v, fn) for v in tree)
    return tree


def swap_trace_count(*, backend: str = "engine_jit",
                     arch: str = "smollm-135m", aligned: bool = True,
                     mesh: Any = None) -> int:
    """Decode jit trace count across one hot swap (the static scenario
    behind the ``swap-trace-count`` budget).

    Builds two weight generations, serves a request on generation 0,
    stages a swap, drains a generation-1 request, and reads the
    engine's true decode trace counter. ``aligned=False`` deliberately
    widens the new generation's DevicePlans (the drift
    ``align_device_plans`` exists to prevent) — the hand-broken twin
    that must push the count to 2.
    """
    import jax
    from repro.configs import get_reduced
    from repro.core.engine import pad_device_plan
    from repro.fleet import build_generation
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.serve import ServeEngine

    cfg = serve_config(get_reduced(arch).replace(n_layers=2),
                       backend=backend)
    model = Model(cfg)
    raw0 = model.init(jax.random.PRNGKey(0))
    raw1 = model.init(jax.random.PRNGKey(1234))
    gen0 = build_generation(model, raw0, gen=0, mesh=mesh)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1,
                            mesh=mesh)
    p1 = gen1.params
    if not aligned:
        p1 = _map_device_plans(
            p1, lambda d: pad_device_plan(
                d, int(np.asarray(d.direct_idx).shape[-1]) + 4))
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=16,
                      page_size=4)
    prompt = tuple(range(1, 9))
    eng.submit(prompt, 4)
    eng.step()
    eng.step()
    eng.swap_params(p1, tag="costcheck")
    eng.submit(prompt, 4)
    while eng.queue or eng.active:
        eng.step()
    return int(eng.stats()["decode_jit_traces"])


def check_budgets(backend_names: list[str], *, mesh: Any = None,
                  budgets_path: str | os.PathLike | None = None,
                  arch: str = "smollm-135m"
                  ) -> tuple[list[dict], list[Finding]]:
    """Evaluate every budget against every applicable backend.

    A budget applies to a backend when the budget's ``backend`` key
    matches (or is absent) and the backend builds the budget's program;
    inapplicable combinations are reported as skips, never findings.
    Returns (report rows with the measured values, findings) — a
    finding per exceeded budget, rule ``cost-budget``.
    """
    from repro.analysis.programs import build_programs

    budgets = load_budgets(budgets_path)["budgets"]
    report: list[dict] = []
    findings: list[Finding] = []
    progs_cache: dict[str, dict[str, Any]] = {}

    def programs_for(bname: str) -> dict[str, Any]:
        if bname not in progs_cache:
            progs_cache[bname] = {p.name: p for p in build_programs(
                bname, mesh=mesh, arch=arch)}
        return progs_cache[bname]

    for b in budgets:
        for bname in backend_names:
            row = {"budget": b["name"], "backend": bname,
                   "program": b["program"], "metric": b["metric"],
                   "max": b["max"]}
            if b.get("backend") is not None and b["backend"] != bname:
                row["skipped"] = f"budget pinned to {b['backend']}"
                report.append(row)
                continue
            metric = b["metric"]
            if metric == "decode_jit_traces":
                if b["program"] not in programs_for(bname):
                    row["skipped"] = "backend builds no such program"
                    report.append(row)
                    continue
                value = float(swap_trace_count(
                    backend=bname, arch=arch, mesh=mesh,
                    aligned=bool(b.get("aligned", True))))
            elif metric.endswith("_growth"):
                base = metric[:-len("_growth")]
                try:
                    value, detail = growth_ratio(bname, b["program"],
                                                 base, mesh=mesh,
                                                 arch=arch)
                except KeyError:
                    row["skipped"] = "backend builds no such program"
                    report.append(row)
                    continue
                row["values"] = detail
            else:
                progs = programs_for(bname)
                if b["program"] not in progs:
                    row["skipped"] = "backend builds no such program"
                    report.append(row)
                    continue
                m = program_metrics(progs[b["program"]])
                if not hasattr(m, metric):
                    raise ValueError(
                        f"budget {b['name']!r}: unknown metric "
                        f"{metric!r} (not a CostMetrics field)")
                value = float(getattr(m, metric))
            row["value"] = value
            row["ok"] = value <= float(b["max"])
            report.append(row)
            if not row["ok"]:
                findings.append(Finding(
                    rule="cost-budget", severity="error",
                    program=b["program"], backend=bname,
                    path=metric, primitive=b["name"],
                    message=f"budget '{b['name']}' exceeded: {metric} = "
                    f"{value:g} > max {b['max']:g}"
                    + (f" — {b['note']}" if b.get("note") else "")))
    return report, findings
