"""Tracelint CLI — the serving-invariant gate CI runs per device leg.

  PYTHONPATH=src python -m repro.analysis.lint \\
      [--backend engine_jit ...] [--mesh data=4] [--rules r1,r2] \\
      [--plans] [--budgets [FILE]] [--prune-baseline] \\
      [--baseline FILE | --write-baseline FILE] [--json OUT] [--list-rules]

Builds every registered backend's serving programs (prefill, donated
decode, paged decode, the DevicePlan forest — ``analysis/programs.py``)
and runs every registered rule against them, honoring each backend's
``lint_exempt`` capability tags. Default backend set: every ``cpu_ok``
backend — the same enumeration the CI serve smoke loops, so a future
``engine_tpu``/``engine_gpu`` is linted the day it registers (on
hardware legs, via ``--backend``).

``--plans`` additionally verifies the plan IR itself (``planlint.py``:
DAG/level-monotone schedule, gather bounds, pad-lane deadness, bundle
round-trip) and ``--budgets`` enforces the static cost budgets
(``costcheck.py`` + ``budgets.json``); both streams merge into the same
findings/baseline/exit-code machinery, so a budget regression fails CI
exactly like a tracelint violation. ``--prune-baseline`` reports
baseline entries no current finding matches (add ``--write-baseline``
to rewrite the file without them).

Exit status 1 iff any non-baselined error-severity finding remains;
``--json`` writes the full findings list (CI uploads it as an artifact).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from repro.analysis.baseline import (load_baseline, save_baseline,
                                     split_baselined)
from repro.analysis.programs import lint_backend
from repro.analysis.rules import get_rule, list_rules
from repro.core.backend import get_backend, list_backends


def _cpu_ok_backends() -> list[str]:
    return [n for n in list_backends() if get_backend(n).cpu_ok]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Static-analysis gate over every backend's serving "
                    "programs (rule catalog: docs/ANALYSIS.md)")
    ap.add_argument("--backend", action="append", default=None,
                    choices=list_backends(), metavar="NAME",
                    help="lint this backend (repeatable; default: every "
                    "cpu_ok backend in the registry)")
    ap.add_argument("--mesh", default=None, metavar="AXIS=N[,AXIS=N]",
                    help="lint under a device mesh, e.g. 'data=4' — adds "
                    "the sharding-integrity evidence (CPU: set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--rules", default=None, metavar="R1,R2",
                    help="restrict to a comma-separated rule subset")
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--plans", action="store_true",
                    help="also verify the plan IR (ExecutionPlan / "
                    "DevicePlan / bundle round-trip) per backend")
    ap.add_argument("--budgets", nargs="?", const=True, default=None,
                    metavar="FILE",
                    help="also enforce static cost budgets (default "
                    "budget file: analysis/budgets.json)")
    ap.add_argument("--prune-baseline", action="store_true",
                    help="report baseline entries matching no current "
                    "finding; with --write-baseline, drop them")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="allowlist of known findings (Finding.key lines); "
                    "baselined findings report but do not fail")
    ap.add_argument("--write-baseline", default=None, metavar="FILE",
                    help="snapshot current findings as a baseline and exit "
                    "0")
    ap.add_argument("--json", default=None, metavar="FILE",
                    help="write the findings report as JSON (CI artifact)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule registry and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for name in list_rules():
            r = get_rule(name)
            print(f"{name:22s} [{r.severity}] ({r.requires}) "
                  f"{r.description}")
        return 0

    only = tuple(args.rules.split(",")) if args.rules else None
    if only:
        for r in only:
            get_rule(r)                     # loud unknown-rule error
    baseline = load_baseline(args.baseline)
    backends = args.backend or _cpu_ok_backends()
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_serve_mesh
        mesh = make_serve_mesh(args.mesh)

    all_findings, report = [], []
    t0 = time.time()
    for name in backends:
        b = get_backend(name)
        progs, findings = lint_backend(name, mesh=mesh, only=only,
                                       batch=args.batch, arch=args.arch)
        all_findings.extend(findings)
        exempt = sorted(getattr(b, "lint_exempt", ()))
        report.append({
            "backend": name,
            "programs": [p.name for p in progs],
            "lint_exempt": exempt,
            "findings": [f.to_json() for f in findings],
        })
        status = (f"{len(findings)} finding(s)" if findings else "clean")
        ex = f" (exempt: {', '.join(exempt)})" if exempt else ""
        print(f"[tracelint] {name:14s} {len(progs)} programs -> "
              f"{status}{ex}")
        for f in findings:
            print(f"  {f.format()}")

    plans_report = budget_report = None
    if args.plans:
        from repro.analysis.planlint import lint_plans
        plans_report, pfindings = lint_plans(backends, mesh=mesh)
        all_findings.extend(pfindings)
        status = (f"{len(pfindings)} finding(s)" if pfindings
                  else "clean")
        print(f"[planlint]  {len(plans_report)} artifact batch(es) -> "
              f"{status}")
        for f in pfindings:
            print(f"  {f.format()}")
    if args.budgets is not None:
        from repro.analysis.costcheck import check_budgets
        bpath = None if args.budgets is True else args.budgets
        budget_report, bfindings = check_budgets(
            backends, mesh=mesh, budgets_path=bpath, arch=args.arch)
        all_findings.extend(bfindings)
        n_eval = sum(1 for r in budget_report if "value" in r)
        print(f"[costcheck] {n_eval} budget evaluation(s) -> "
              f"{len(bfindings) if bfindings else 'clean'}"
              f"{' finding(s)' if bfindings else ''}")
        for f in bfindings:
            print(f"  {f.format()}")

    if args.prune_baseline:
        from repro.analysis.baseline import stale_keys
        stale = stale_keys(baseline, all_findings)
        for k in stale:
            print(f"[baseline] stale: {k}")
        print(f"[baseline] {len(stale)} stale entr"
              f"{'y' if len(stale) == 1 else 'ies'} of {len(baseline)}")
        if args.write_baseline:
            kept = sorted(frozenset(baseline) - set(stale))
            with open(args.write_baseline, "w") as f:
                f.write("# tracelint baseline — one Finding.key per "
                        "line\n")
                for k in kept:
                    f.write(k + "\n")
            print(f"[baseline] wrote {len(kept)} key(s) to "
                  f"{args.write_baseline}")
            return 0
    elif args.write_baseline:
        n = save_baseline(args.write_baseline, all_findings)
        print(f"[tracelint] wrote {n} baseline key(s) to "
              f"{args.write_baseline}")
        return 0

    new, suppressed = split_baselined(all_findings, baseline)
    failing = [f for f in new if f.severity == "error"]
    dt = time.time() - t0
    summary = {
        "backends": backends,
        "mesh": args.mesh,
        "rules": list(only) if only else list(list_rules()),
        "findings": len(all_findings),
        "baselined": len(suppressed),
        "failing": len(failing),
        "seconds": round(dt, 2),
    }
    if args.json:
        doc = {"summary": summary, "backends": report}
        if plans_report is not None:
            doc["plans"] = plans_report
        if budget_report is not None:
            doc["budgets"] = budget_report
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
    print(f"[tracelint] {len(backends)} backend(s)"
          f"{' on mesh ' + args.mesh if args.mesh else ''}: "
          f"{len(all_findings)} finding(s), {len(suppressed)} baselined, "
          f"{len(failing)} failing ({dt:.1f}s)")
    return 1 if failing else 0


if __name__ == "__main__":
    sys.exit(main())
