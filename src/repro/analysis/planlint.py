"""Plan-IR verifier: machine-check the transitive DAG before it runs.

Tracelint (``analysis/rules.py``) guards the *lowered programs*; this
module guards the *plan artifacts* those programs execute — the
:class:`~repro.core.engine.ExecutionPlan` schedule, its compiled
:class:`~repro.core.engine.DevicePlan` gather maps, and the persisted
plan bundles the fleet layer ships planner→server. The paper's whole
speedup argument is that the transitive-reuse structure is a DAG whose
execution order is analyzable ahead of time; these rules are that
analysis made executable: a corrupted plan is refused with a named
finding *before* it can silently compute the wrong GEMM.

Rules are registered objects in the same style as ``rules.py`` (one
process-level registry, loud duplicates) but with their own registry:
they check numpy plan IR, not jaxprs. Verification is **fail-fast at
rule granularity**: rules run in registration order and the first rule
that fires reports alone — downstream rules assume upstream invariants
(bounds before graph shape before DAG order), so one corruption yields
exactly one finding whose path names the bad field.

The verifier is wired as a *gate* at the three trust boundaries a plan
crosses (set ``REPRO_PLANLINT=0`` to disable all three):

* ``PlanCache`` publish (``core/plancache.py``) — a freshly built plan
  (and its compiled device lowering) is verified before other callers
  can coalesce onto it;
* ``fleet.bundles.load_bundles`` on the server role — every bundle file
  is structurally verified **before** its SHA-256 is checked (a
  truncated/garbage npz is a planlint refusal, not a hash mismatch),
  and the manifest itself is a checked artifact;
* ``ServeEngine.swap_params`` staging — a hot-swap generation's
  embedded DevicePlans are verified before they are staged, so a
  corrupt replan can never reach the decode step.

Entry points: :func:`verify_plan`, :func:`verify_device_plan`,
:func:`verify_bundle_file`, :func:`verify_manifest`, the raising
``gate_*`` twins, and :func:`lint_plans` (the ``--plans`` half of
``python -m repro.analysis.lint``).
"""
from __future__ import annotations

import dataclasses
import os
import tempfile
from typing import Any, Iterator

import numpy as np

from repro.analysis.rules import Finding

__all__ = ["PlanArtifact", "PlanRule", "PlanVerificationError",
           "register_plan_rule", "unregister_plan_rule", "get_plan_rule",
           "list_plan_rules", "enabled", "verify_plan",
           "verify_device_plan", "verify_bundle_file", "verify_manifest",
           "gate_plan", "gate_device", "gate_params",
           "iter_device_plans", "lint_plans"]


def enabled() -> bool:
    """The gates' kill switch: ``REPRO_PLANLINT=0`` disables them."""
    return os.environ.get("REPRO_PLANLINT", "1").lower() not in (
        "0", "false", "no", "off")


class PlanVerificationError(ValueError):
    """A plan artifact failed verification at a trust boundary."""

    def __init__(self, findings: list[Finding], where: str) -> None:
        self.findings = list(findings)
        self.where = where
        lines = "\n  ".join(f.format() for f in self.findings)
        super().__init__(
            f"planlint: {len(self.findings)} finding(s) at gate "
            f"'{where}':\n  {lines}")


@dataclasses.dataclass
class PlanArtifact:
    """One verifiable plan artifact with everything plan rules inspect.

    ``kind`` selects which rules apply: ``"plan"`` (host
    ``ExecutionPlan``), ``"device"`` (compiled ``DevicePlan``, possibly
    stacked/padded; ``device_np`` is its leaves pulled to host numpy),
    ``"manifest"`` (a fleet bundle manifest dict, with ``bundle_dir``
    for on-disk file checks). ``plan`` rides along on device artifacts
    when the caller has it, enabling the plan↔device agreement rule.
    """
    kind: str
    name: str                       # Finding.program label
    backend: str | None = None
    plan: Any = None                # ExecutionPlan
    device: Any = None              # DevicePlan
    device_np: dict[str, np.ndarray] | None = None
    manifest: dict[str, Any] | None = None
    bundle_dir: str | None = None


class PlanRule:
    """Base class for one plan-IR invariant (registry mirror of
    :class:`repro.analysis.rules.Rule`, over plan artifacts).

    ``kinds`` names the artifact kinds the rule applies to; a rule
    reports **at most one finding** (the first violation, with the
    total count in the message) so the fail-fast driver's
    one-corruption-one-finding contract holds.
    """
    name: str = ""
    severity: str = "error"
    kinds: tuple[str, ...] = ("plan",)
    description: str = ""

    def check(self, art: PlanArtifact) -> list[Finding]:
        raise NotImplementedError

    def _finding(self, art: PlanArtifact, message: str, *,
                 path: str = "", field: str | None = None) -> Finding:
        return Finding(rule=self.name, severity=self.severity,
                       program=art.name, backend=art.backend,
                       path=path, primitive=field, message=message)


_PLAN_REGISTRY: dict[str, PlanRule] = {}


def register_plan_rule(rule: PlanRule, *, replace: bool = False) -> PlanRule:
    name = getattr(rule, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError(f"plan rule must declare a non-empty string "
                         f"name, got {name!r}")
    if name in _PLAN_REGISTRY and not replace:
        raise ValueError(f"plan rule '{name}' is already registered "
                         f"({_PLAN_REGISTRY[name]!r}); pass replace=True "
                         f"to override")
    _PLAN_REGISTRY[name] = rule
    return rule


def unregister_plan_rule(name: str) -> PlanRule:
    if name not in _PLAN_REGISTRY:
        raise KeyError(f"unknown plan rule {name!r}; registered: "
                       f"{', '.join(sorted(_PLAN_REGISTRY))}")
    return _PLAN_REGISTRY.pop(name)


def get_plan_rule(name: str) -> PlanRule:
    try:
        return _PLAN_REGISTRY[name]
    except (KeyError, TypeError):
        raise KeyError(f"unknown plan rule {name!r}; registered: "
                       f"{', '.join(sorted(_PLAN_REGISTRY))}") from None


def list_plan_rules() -> tuple[str, ...]:
    return tuple(_PLAN_REGISTRY)


def _run(art: PlanArtifact) -> list[Finding]:
    """Registration-order fail-fast: first firing rule reports alone."""
    for rule in _PLAN_REGISTRY.values():
        if art.kind not in rule.kinds:
            continue
        findings = rule.check(art)
        if findings:
            return findings
    return []


# ---------------------------------------------------------------------------
# numpy helpers shared by several rules
# ---------------------------------------------------------------------------

def _popcount(v: np.ndarray, t: int) -> np.ndarray:
    v = np.asarray(v, np.int64)
    return ((v[..., None] >> np.arange(t)) & 1).sum(-1)


def _first_bad(mask: np.ndarray) -> tuple[int, ...]:
    """Index tuple of the first True entry of a boolean mask."""
    flat = int(np.flatnonzero(np.asarray(mask).reshape(-1))[0])
    return tuple(int(i) for i in
                 np.unravel_index(flat, np.asarray(mask).shape))


def _idx(name: str, where: tuple[int, ...]) -> str:
    return f"{name}[{', '.join(map(str, where))}]"


# ---------------------------------------------------------------------------
# ExecutionPlan rules (host plan IR)
# ---------------------------------------------------------------------------

class PlanShape(PlanRule):
    """The plan's arrays agree on one layer signature."""
    name = "plan-shape"
    kinds = ("plan",)
    description = ("rows/signs/steps/direct arrays all match the "
                   "(t, bits, n, k, groups) signature; k divides into "
                   "whole tiles and groups into whole tile sets")

    def check(self, art):
        p = art.plan
        t, bits = int(p.t), int(p.bits)
        if t <= 0 or bits <= 0 or p.n <= 0 or p.k <= 0:
            return [self._finding(
                art, f"non-positive signature (t={p.t}, bits={p.bits}, "
                f"n={p.n}, k={p.k})", path="t", field="t")]
        if p.k % t:
            return [self._finding(
                art, f"k={p.k} is not a whole number of t={t} tiles",
                path="k", field="k")]
        j = p.k // t
        if p.groups < 1 or j % p.groups:
            return [self._finding(
                art, f"groups={p.groups} does not divide the "
                f"{j}-tile axis", path="groups", field="groups")]
        rows = np.asarray(p.rows)
        if rows.shape != (bits, p.n, j):
            return [self._finding(
                art, f"rows shape {rows.shape} != (bits, n, k//t)="
                f"({bits}, {p.n}, {j})", path="rows", field="rows")]
        if np.asarray(p.signs).shape != (bits,):
            return [self._finding(
                art, f"signs shape {np.asarray(p.signs).shape} != "
                f"(bits,)=({bits},)", path="signs", field="signs")]
        d = np.asarray(p.direct_tile).shape
        if (np.asarray(p.direct_node).shape != d
                or np.asarray(p.direct_bits).shape != d + (t,)):
            return [self._finding(
                art, f"direct arrays disagree: tile{d} node"
                f"{np.asarray(p.direct_node).shape} bits"
                f"{np.asarray(p.direct_bits).shape} (want (D,), (D,), "
                f"(D, {t}))", path="direct_bits", field="direct_bits")]
        if len(p.steps) > t:
            return [self._finding(
                art, f"{len(p.steps)} level steps > t={t} (a node has "
                f"at most t bits)", path="steps", field="steps")]
        for i, s in enumerate(p.steps):
            ln = {np.asarray(a).shape for a in
                  (s.tile, s.node, s.prefix, s.bit)}
            if len(ln) != 1 or any(len(sh) != 1 for sh in ln):
                return [self._finding(
                    art, f"steps[{i}] edge arrays disagree on length: "
                    f"{sorted(ln)}", path=f"steps[{i}]", field="steps")]
        return []


class PlanBounds(PlanRule):
    """Every plan index is inside the structure it addresses."""
    name = "plan-bounds"
    kinds = ("plan",)
    description = ("rows < 2^t, step tiles/nodes/prefixes/bits and "
                   "direct nodes inside the (J, 2^t, t) index spaces, "
                   "direct_bits in {0, 1}")

    def check(self, art):
        p = art.plan
        t, size, j = int(p.t), 1 << int(p.t), p.k // p.t
        checks = [("rows", np.asarray(p.rows), 0, size),
                  ("direct_tile", np.asarray(p.direct_tile), 0, j),
                  ("direct_node", np.asarray(p.direct_node), 0, size)]
        for i, s in enumerate(p.steps):
            checks += [(f"steps[{i}].tile", np.asarray(s.tile), 0, j),
                       (f"steps[{i}].node", np.asarray(s.node), 0, size),
                       (f"steps[{i}].prefix", np.asarray(s.prefix), 0,
                        size),
                       (f"steps[{i}].bit", np.asarray(s.bit), 0, t)]
        for name, arr, lo, hi in checks:
            bad = (arr < lo) | (arr >= hi)
            if bad.any():
                w = _first_bad(bad)
                return [self._finding(
                    art, f"{int(bad.sum())} value(s) outside [{lo}, "
                    f"{hi}): first {_idx(name, w)} = "
                    f"{int(arr[w])}", path=_idx(name, w),
                    field=name.split("[")[0].split(".")[-1])]
        db = np.asarray(p.direct_bits)
        bad = (db != 0) & (db != 1)
        if bad.any():
            w = _first_bad(bad)
            return [self._finding(
                art, f"direct_bits must be a {{0,1}} mask; first "
                f"{_idx('direct_bits', w)} = {int(db[w])}",
                path=_idx("direct_bits", w), field="direct_bits")]
        return []


class PlanDirectPattern(PlanRule):
    """Direct-dispatch bit masks reconstruct their node values."""
    name = "plan-direct-pattern"
    kinds = ("plan",)
    description = ("each direct node's {0,1} bit mask is the binary "
                   "decomposition of its node value — direct dispatch "
                   "computes subset sums straight from the mask")

    def check(self, art):
        p = art.plan
        db = np.asarray(p.direct_bits, np.int64)
        if db.size == 0:
            return []
        got = (db << np.arange(p.t)).sum(-1)
        bad = got != np.asarray(p.direct_node, np.int64)
        if bad.any():
            w = _first_bad(bad)
            return [self._finding(
                art, f"{int(bad.sum())} direct bit mask(s) do not "
                f"decompose their node: first direct_bits[{w[0]}] sums "
                f"to {int(got[w])} but direct_node[{w[0]}] = "
                f"{int(p.direct_node[w[0]])}",
                path=f"direct_bits[{w[0]}]", field="direct_bits")]
        return []


class PlanScheduleLevels(PlanRule):
    """Steps are level-homogeneous with single-bit covering edges."""
    name = "plan-schedule-levels"
    kinds = ("plan",)
    description = ("steps[i] holds exactly the Hamming-level-(i+1) "
                   "nodes and every edge covers: node ^ prefix is the "
                   "single bit the step names")

    def check(self, art):
        p = art.plan
        for i, s in enumerate(p.steps):
            node = np.asarray(s.node, np.int64)
            if node.size == 0:
                continue
            lv = _popcount(node, p.t)
            bad = lv != (i + 1)
            if bad.any():
                w = _first_bad(bad)
                return [self._finding(
                    art, f"{int(bad.sum())} node(s) in steps[{i}] "
                    f"(level {i + 1}) at the wrong Hamming level: first "
                    f"{_idx(f'steps[{i}].node', w)} = {int(node[w])} "
                    f"(level {int(lv[w])}) — a reordered level executes "
                    f"before its prefixes exist",
                    path=_idx(f"steps[{i}].node", w), field="node")]
            edge = node ^ np.asarray(s.prefix, np.int64)
            want = np.int64(1) << np.asarray(s.bit, np.int64)
            bad = edge != want
            if bad.any():
                w = _first_bad(bad)
                return [self._finding(
                    art, f"{int(bad.sum())} non-covering edge(s) in "
                    f"steps[{i}]: first {_idx(f'steps[{i}].prefix', w)} "
                    f"= {int(s.prefix[w])} vs node {int(node[w])} "
                    f"(xor {int(edge[w])}, declared bit "
                    f"{int(s.bit[w])})",
                    path=_idx(f"steps[{i}].prefix", w), field="prefix")]
        return []


class PlanScheduleDag(PlanRule):
    """The reuse schedule is an acyclic, level-monotone forest."""
    name = "plan-schedule-dag"
    kinds = ("plan",)
    description = ("each (tile, node) is produced at most once, and "
                   "every level-l edge's prefix was produced strictly "
                   "earlier (direct dispatch, an earlier level, or the "
                   "empty node 0)")

    def check(self, art):
        p = art.plan
        size = 1 << int(p.t)
        direct = set(zip(np.asarray(p.direct_tile, np.int64).tolist(),
                         np.asarray(p.direct_node, np.int64).tolist()))
        produced: set[tuple[int, int]] = set(direct)
        if len(direct) != np.asarray(p.direct_tile).size:
            return [self._finding(
                art, "duplicate (tile, node) in direct dispatch — a "
                "node produced twice races its own scatter",
                path="direct_node", field="direct_node")]
        earlier = set(produced)      # produced before the current level
        for i, s in enumerate(p.steps):
            tiles = np.asarray(s.tile, np.int64).tolist()
            nodes = np.asarray(s.node, np.int64).tolist()
            prefixes = np.asarray(s.prefix, np.int64).tolist()
            here = []
            for e, (tl, nd, pre) in enumerate(
                    zip(tiles, nodes, prefixes)):
                if (tl, nd) in produced:
                    return [self._finding(
                        art, f"(tile {tl}, node {nd}) produced twice — "
                        f"second production at steps[{i}].node[{e}]",
                        path=f"steps[{i}].node[{e}]", field="node")]
                if pre != 0 and (tl, pre) not in earlier:
                    return [self._finding(
                        art, f"steps[{i}].prefix[{e}] gathers (tile "
                        f"{tl}, node {pre}) which is not produced at "
                        f"any earlier level — the schedule is not a "
                        f"DAG in execution order (a same-level or "
                        f"later production would read a stale psum "
                        f"row)", path=f"steps[{i}].prefix[{e}]",
                        field="prefix")]
                produced.add((tl, nd))
                here.append((tl, nd))
            earlier.update(here)
        bad = [v for _, v in produced if not 0 <= v < size]
        del bad  # bounds already guaranteed by plan-bounds (fail-fast)
        return []


# ---------------------------------------------------------------------------
# DevicePlan rules (compiled gather maps, possibly stacked/padded)
# ---------------------------------------------------------------------------

def _device_np(device: Any) -> dict[str, np.ndarray]:
    from repro.core.engine import DEVICE_DATA_FIELDS
    return {f: np.asarray(getattr(device, f)) for f in DEVICE_DATA_FIELDS}


def _device_dims(device: Any) -> tuple[int, int, int, int]:
    """(t, J, R, K) of a device plan's metadata signature."""
    t = int(device.t)
    j = int(device.k) // t
    return t, j, j * (1 << t), int(device.k)


class DeviceShape(PlanRule):
    """Stack-axis consistency: every leaf agrees on one lead shape."""
    name = "device-shape"
    kinds = ("device",)
    description = ("all DevicePlan leaves share the same leading "
                   "(stack) axes and their core dims match the "
                   "(t, bits, n, k, groups) signature — the contract "
                   "compile_plans/pad_device_plan preserve")

    def check(self, art):
        d, f = art.device, art.device_np
        t = int(d.t)
        if t <= 0 or d.k <= 0 or d.k % t:
            return [self._finding(
                art, f"signature k={d.k} is not a whole number of "
                f"t={t} tiles", path="k", field="k")]
        tt, j, r, _k = _device_dims(d)
        if int(d.groups) < 1 or j % int(d.groups):
            return [self._finding(
                art, f"groups={d.groups} does not divide the {j}-tile "
                f"axis", path="groups", field="groups")]
        ls = f["level_src"]
        if ls.ndim < 2 or ls.shape[-2:] != (tt, r):
            return [self._finding(
                art, f"level_src core shape {ls.shape[-2:] if ls.ndim >= 2 else ls.shape} != (t, J*2^t)="
                f"({tt}, {r})", path="level_src", field="level_src")]
        lead = ls.shape[:-2]
        dwidth = f["direct_idx"].shape[-1] if f["direct_idx"].ndim else 0
        want = {"level_xsrc": lead + (tt, r),
                "direct_idx": lead + (dwidth,),
                "direct_x_idx": lead + (dwidth, tt),
                "direct_bits": lead + (dwidth, tt),
                "gather_idx": lead + (int(d.bits), int(d.n), j),
                "signs": lead + (int(d.bits),)}
        for name, shape in want.items():
            if f[name].shape != shape:
                return [self._finding(
                    art, f"{name} shape {f[name].shape} != {shape} — "
                    f"leaves disagree on the stack axes / signature "
                    f"(lead {lead})", path=name, field=name)]
        if dwidth < 1:
            return [self._finding(
                art, "direct_idx width 0: compile_plan always emits at "
                "least one (possibly dead) direct lane",
                path="direct_idx", field="direct_idx")]
        return []


class DeviceBounds(PlanRule):
    """Every gather/scatter index is inside its table (or the
    sanctioned one-past-end row)."""
    name = "device-bounds"
    kinds = ("device",)
    description = ("level_src/gather_idx < J*2^t, level_xsrc <= K "
                   "(K = the pinned zero activation row), direct_idx "
                   "<= J*2^t (= the dropped pad target), direct_x_idx "
                   "< K, direct_bits in {0, 1}")

    def check(self, art):
        d, f = art.device, art.device_np
        _t, _j, r, k = _device_dims(d)
        checks = [("level_src", f["level_src"], r),
                  ("level_xsrc", f["level_xsrc"], k + 1),
                  ("direct_idx", f["direct_idx"], r + 1),
                  ("direct_x_idx", f["direct_x_idx"], k),
                  ("gather_idx", f["gather_idx"], r)]
        for name, arr, hi in checks:
            bad = (arr < 0) | (arr >= hi)
            if bad.any():
                w = _first_bad(bad)
                return [self._finding(
                    art, f"{int(bad.sum())} index value(s) outside "
                    f"[0, {hi}): first {_idx(name, w)} = "
                    f"{int(arr[w])} — an out-of-bounds gather clamps "
                    f"silently on device and corrupts the GEMM",
                    path=_idx(name, w), field=name)]
        db = f["direct_bits"]
        bad = (db != 0) & (db != 1)
        if bad.any():
            w = _first_bad(bad)
            return [self._finding(
                art, f"direct_bits must be a {{0,1}} mask; first "
                f"{_idx('direct_bits', w)} = {int(db[w])}",
                path=_idx("direct_bits", w), field="direct_bits")]
        return []


class DeviceIdentityLanes(PlanRule):
    """Identity lanes gather themselves plus exactly the zero row."""
    name = "device-identity-lanes"
    kinds = ("device",)
    description = ("level_src[l, r] == r iff level_xsrc[l, r] == K: a "
                   "self-gather adding a real activation row double-"
                   "counts it; a cross-gather adding the zero row "
                   "overwrites a psum with a copy")

    def check(self, art):
        d, f = art.device, art.device_np
        t, _j, r, k = _device_dims(d)
        ls = f["level_src"].reshape(-1, t, r)
        lx = f["level_xsrc"].reshape(-1, t, r)
        rid = np.arange(r, dtype=ls.dtype)
        identity = ls == rid[None, None, :]
        zero = lx == k
        bad = identity != zero
        if bad.any():
            s, lv, row = _first_bad(bad)
            kind = ("identity lane adds real activation row "
                    f"{int(lx[s, lv, row])}" if identity[s, lv, row]
                    else f"executed lane (src {int(ls[s, lv, row])}) "
                    f"adds the pinned zero row")
            where = ((s, lv, row) if f["level_src"].ndim > 2
                     else (lv, row))
            return [self._finding(
                art, f"{int(bad.sum())} lane(s) break the identity "
                f"contract: first {_idx('level_xsrc', where)} — {kind}",
                path=_idx("level_xsrc", where), field="level_xsrc")]
        return []


class DeviceLevelMonotone(PlanRule):
    """The gather schedule is acyclic: sources settle strictly
    earlier."""
    name = "device-level-monotone"
    kinds = ("device",)
    description = ("each psum row is executed at most once across the "
                   "level maps, and an executed row's source row is "
                   "never executed at the same or a later level — the "
                   "device-side statement of DAG acyclicity")

    def check(self, art):
        d, f = art.device, art.device_np
        t, _j, r, _k = _device_dims(d)
        stacked = f["level_src"].ndim > 2
        ls_all = f["level_src"].reshape(-1, t, r)
        rid = np.arange(r, dtype=ls_all.dtype)
        for s in range(ls_all.shape[0]):
            ls = ls_all[s]
            execd = ls != rid[None, :]
            times = execd.sum(0)
            if (times > 1).any():
                row = int(np.flatnonzero(times > 1)[0])
                lvls = np.flatnonzero(execd[:, row]).tolist()
                where = ((s, lvls[1], row) if stacked
                         else (lvls[1], row))
                return [self._finding(
                    art, f"psum row {row} is executed at "
                    f"{int(times[row])} levels {lvls} — a node is "
                    f"computed once; the later execution overwrites it",
                    path=_idx("level_src", where), field="level_src")]
            exec_level = np.where(execd.any(0), execd.argmax(0), -1)
            lv_i, row_i = np.nonzero(execd)
            src = ls[lv_i, row_i]
            bad = exec_level[src] >= lv_i
            if bad.any():
                b = int(np.flatnonzero(bad)[0])
                lv, row = int(lv_i[b]), int(row_i[b])
                where = (s, lv, row) if stacked else (lv, row)
                return [self._finding(
                    art, f"{int(bad.sum())} edge(s) violate level "
                    f"monotonicity: first {_idx('level_src', where)} "
                    f"gathers row {int(src[b])}, which is itself "
                    f"executed at level {int(exec_level[src[b]])} (>= "
                    f"{lv}) — a cycle or reordered level in the reuse "
                    f"graph reads an unsettled psum",
                    path=_idx("level_src", where), field="level_src")]
        return []


class DeviceDirectDispatch(PlanRule):
    """Pad lanes are provably dead; live lanes are one-writer."""
    name = "device-direct-dispatch"
    kinds = ("device",)
    description = ("pad lanes (target J*2^t) carry all-zero bit masks "
                   "(the pad_device_plan contract), live targets are "
                   "unique, and no live target is also level-executed")

    def check(self, art):
        d, f = art.device, art.device_np
        t, _j, r, _k = _device_dims(d)
        stacked = f["direct_idx"].ndim > 1
        di_all = f["direct_idx"].reshape(-1, f["direct_idx"].shape[-1])
        db_all = f["direct_bits"].reshape(-1,
                                          f["direct_bits"].shape[-2], t)
        ls_all = f["level_src"].reshape(-1, t, r)
        rid = np.arange(r)
        for s in range(di_all.shape[0]):
            di, db = di_all[s], db_all[s]
            pad = di == r
            live_bits = db.any(-1)
            bad = pad & live_bits
            if bad.any():
                lane = int(np.flatnonzero(bad)[0])
                bit = int(np.flatnonzero(db[lane])[0])
                where = (s, lane, bit) if stacked else (lane, bit)
                return [self._finding(
                    art, f"{int(bad.sum())} pad lane(s) are not dead: "
                    f"first {_idx('direct_bits', where)} = "
                    f"{int(db[lane, bit])} on a lane whose scatter "
                    f"target is the dropped row {r} — pad lanes must "
                    f"be bit-exact no-ops (pad_device_plan contract) "
                    f"or a hot-swap pad changes the GEMM",
                    path=_idx("direct_bits", where),
                    field="direct_bits")]
            live = di[~pad]
            if live.size != np.unique(live).size:
                vals, counts = np.unique(live, return_counts=True)
                dup = int(vals[counts > 1][0])
                lane = int(np.flatnonzero(di == dup)[1])
                where = (s, lane) if stacked else (lane,)
                return [self._finding(
                    art, f"direct target row {dup} is scattered by "
                    f"multiple lanes — last-writer-wins makes the "
                    f"psum nondeterministic",
                    path=_idx("direct_idx", where), field="direct_idx")]
            execd_rows = rid[(ls_all[s] != rid[None, :]).any(0)]
            clash = np.isin(live, execd_rows)
            if clash.any():
                lane = int(np.flatnonzero(~pad)[np.flatnonzero(clash)[0]])
                where = (s, lane) if stacked else (lane,)
                return [self._finding(
                    art, f"direct target row {int(di[lane])} is also "
                    f"executed by the level maps — the node would be "
                    f"computed twice",
                    path=_idx("direct_idx", where), field="direct_idx")]
        return []


class PlanDeviceAgreement(PlanRule):
    """The device lowering is exactly what the host plan compiles to."""
    name = "plan-device-agreement"
    kinds = ("device",)
    description = ("when the host plan is available and the device "
                   "plan is unstacked, recompiling the plan (at the "
                   "observed direct pad) reproduces every leaf bit-"
                   "exactly — catches content corruption that is "
                   "individually well-formed")

    def check(self, art):
        if art.plan is None:
            return []
        f = art.device_np
        if f["level_src"].ndim != 2:
            return []                 # stacked: per-slice plans unknown
        from repro.core.engine import (DEVICE_DATA_FIELDS, compile_plan,
                                       pad_device_plan)
        want = compile_plan(art.plan)
        pad = f["direct_idx"].shape[-1]
        if pad > want.direct_idx.shape[-1]:
            want = pad_device_plan(want, pad)
        for name in DEVICE_DATA_FIELDS:
            exp = np.asarray(getattr(want, name))
            got = f[name]
            if exp.shape != got.shape or not np.array_equal(exp, got):
                bad = (exp != got if exp.shape == got.shape
                       else np.ones(1, bool))
                w = (_first_bad(bad) if exp.shape == got.shape else ())
                return [self._finding(
                    art, f"{name} does not match the host plan's "
                    f"compilation"
                    + (f": first divergence at {_idx(name, w)} "
                       f"(got {int(got[w])}, plan compiles to "
                       f"{int(exp[w])})" if w else
                       f" (shape {got.shape} vs {exp.shape})"),
                    path=_idx(name, w) if w else name, field=name)]
        return []


# ---------------------------------------------------------------------------
# Bundle rules (fleet manifest + persisted npz files)
# ---------------------------------------------------------------------------

class BundleManifest(PlanRule):
    """The fleet manifest is internally coherent before any file is
    trusted."""
    name = "bundle-manifest"
    kinds = ("manifest",)
    description = ("manifest.json carries the format/backend/"
                   "engine_config/fingerprint keys, layer leads match "
                   "their file lists (unique in-bounds index tuples), "
                   "and every referenced file exists")

    _REQUIRED = ("format", "backend", "engine_config",
                 "weights_fingerprint", "n_layers", "n_files", "layers")

    def check(self, art):
        m = art.manifest
        if not isinstance(m, dict):
            return [self._finding(
                art, f"manifest is {type(m).__name__}, not a dict",
                path="manifest", field="manifest")]
        missing = [k for k in self._REQUIRED if k not in m]
        if missing:
            return [self._finding(
                art, f"manifest is missing key(s) {missing}",
                path=missing[0], field=missing[0])]
        ec = m["engine_config"]
        if not isinstance(ec, dict) or not {"w_bits", "t"} <= set(ec):
            return [self._finding(
                art, f"engine_config {ec!r} lacks w_bits/t",
                path="engine_config", field="engine_config")]
        layers = m["layers"]
        if not isinstance(layers, dict):
            return [self._finding(
                art, f"layers is {type(layers).__name__}, not a dict",
                path="layers", field="layers")]
        if m["n_layers"] != len(layers):
            return [self._finding(
                art, f"n_layers={m['n_layers']} but the manifest "
                f"carries {len(layers)} layer(s)", path="n_layers",
                field="n_layers")]
        n_files = 0
        for lpath, meta in layers.items():
            where = f"layers[{lpath!r}]"
            for key in ("lead", "groups", "files"):
                if key not in meta:
                    return [self._finding(
                        art, f"{where} is missing '{key}'",
                        path=f"{where}.{key}", field=key)]
            lead = tuple(int(v) for v in meta["lead"])
            n_slices = int(np.prod(lead)) if lead else 1
            files = meta["files"]
            if len(files) != n_slices:
                return [self._finding(
                    art, f"{where} lead {list(lead)} implies "
                    f"{n_slices} slice file(s), manifest lists "
                    f"{len(files)}", path=f"{where}.files",
                    field="files")]
            seen: set[tuple[int, ...]] = set()
            for fi, e in enumerate(files):
                fwhere = f"{where}.files[{fi}]"
                miss = [k for k in ("file", "index", "sha256")
                        if k not in e]
                if miss:
                    return [self._finding(
                        art, f"{fwhere} is missing {miss}",
                        path=f"{fwhere}.{miss[0]}", field=miss[0])]
                idx = tuple(int(v) for v in e["index"])
                if len(idx) != len(lead) or any(
                        not 0 <= v < b for v, b in zip(idx, lead)):
                    return [self._finding(
                        art, f"{fwhere}.index {list(idx)} is outside "
                        f"lead {list(lead)}", path=f"{fwhere}.index",
                        field="index")]
                if idx in seen:
                    return [self._finding(
                        art, f"{fwhere}.index {list(idx)} repeats an "
                        f"earlier slice", path=f"{fwhere}.index",
                        field="index")]
                seen.add(idx)
                if art.bundle_dir is not None and not os.path.exists(
                        os.path.join(art.bundle_dir, str(e["file"]))):
                    return [self._finding(
                        art, f"{fwhere}.file {e['file']!r} does not "
                        f"exist in {art.bundle_dir}",
                        path=f"{fwhere}.file", field="file")]
                n_files += 1
        if m["n_files"] != n_files:
            return [self._finding(
                art, f"n_files={m['n_files']} but the layer tables "
                f"list {n_files} file(s)", path="n_files",
                field="n_files")]
        return []


for _r in (PlanShape(), PlanBounds(), PlanDirectPattern(),
           PlanScheduleLevels(), PlanScheduleDag(), DeviceShape(),
           DeviceBounds(), DeviceIdentityLanes(), DeviceLevelMonotone(),
           DeviceDirectDispatch(), PlanDeviceAgreement(),
           BundleManifest()):
    register_plan_rule(_r)
del _r


# ---------------------------------------------------------------------------
# Verification entry points
# ---------------------------------------------------------------------------

def verify_plan(plan: Any, *, backend: str | None = None,
                name: str = "plan") -> list[Finding]:
    """Run the ExecutionPlan rules; returns the (fail-fast) findings."""
    return _run(PlanArtifact(kind="plan", name=name, backend=backend,
                             plan=plan))


def verify_device_plan(device: Any, plan: Any = None, *,
                       backend: str | None = None,
                       name: str = "device-plan") -> list[Finding]:
    """Run the DevicePlan rules (plus plan↔device agreement when the
    host plan is supplied). Leaves are pulled to host numpy once;
    sharded leaves are gathered (lint-sized plans only)."""
    return _run(PlanArtifact(kind="device", name=name, backend=backend,
                             plan=plan, device=device,
                             device_np=_device_np(device)))


def verify_manifest(manifest: Any, *, bundle_dir: str | None = None,
                    backend: str | None = None,
                    name: str = "bundle-manifest") -> list[Finding]:
    """Run the manifest-coherence rules over a fleet bundle manifest."""
    return _run(PlanArtifact(kind="manifest", name=name, backend=backend,
                             manifest=manifest, bundle_dir=bundle_dir))


def verify_bundle_file(path: str | os.PathLike, *,
                       backend: str | None = None) -> list[Finding]:
    """Structurally verify one persisted plan bundle ``.npz``.

    Parses the file (an unreadable/truncated npz is itself a finding —
    this runs *before* any hash check at the bundle-load gate), then
    runs the plan rules on the stored ExecutionPlan and, when the file
    carries a device lowering, the device rules plus plan↔device
    agreement against the stored plan.
    """
    name = os.path.basename(str(path))
    try:
        from repro.core.engine import ExecutionPlan
        bundle = ExecutionPlan.load_bundle(path)
    except Exception as e:                      # noqa: BLE001 — any parse
        return [Finding(
            rule="bundle-file", severity="error", program=name,
            backend=backend, path=str(path), primitive="npz",
            message=f"bundle file is unreadable as a plan npz "
            f"({type(e).__name__}: {e}) — truncated or corrupt "
            f"artifact refused before any hash comparison")]
    findings = verify_plan(bundle.plan, backend=backend, name=name)
    if not findings and bundle.device is not None:
        findings = verify_device_plan(bundle.device, bundle.plan,
                                      backend=backend, name=name)
    return findings


# ---------------------------------------------------------------------------
# Gates (the raising twins — wired at the trust boundaries)
# ---------------------------------------------------------------------------

def _require(findings: list[Finding], where: str) -> None:
    if findings:
        raise PlanVerificationError(findings, where)


def gate_plan(plan: Any, *, where: str,
              backend: str | None = None) -> None:
    """Raise :class:`PlanVerificationError` unless ``plan`` verifies."""
    if enabled():
        _require(verify_plan(plan, backend=backend), where)


def gate_device(device: Any, plan: Any = None, *, where: str,
                backend: str | None = None) -> None:
    """Raise unless the compiled ``device`` plan verifies.

    ``TransitiveBackend.compile`` may return any payload; only the
    canonical ``DevicePlan`` lowering is verifiable here, so other
    payloads pass through unexamined (their backend owns their format).
    """
    if not enabled():
        return
    from repro.core.engine import DevicePlan
    if not isinstance(device, DevicePlan):
        return
    import jax
    if any(isinstance(leaf, jax.core.Tracer)
           for leaf in jax.tree_util.tree_leaves(device)):
        # compiled inside a trace (plan resolution at trace time):
        # leaves are symbolic, so there is nothing to read — the host
        # plan already passed the publish gate on concrete arrays
        return
    _require(verify_device_plan(device, plan, backend=backend), where)


def gate_manifest(manifest: Any, *, where: str,
                  bundle_dir: str | None = None,
                  backend: str | None = None) -> None:
    """Raise unless the bundle manifest is coherent."""
    if enabled():
        _require(verify_manifest(manifest, bundle_dir=bundle_dir,
                                 backend=backend), where)


def gate_bundle_file(path: Any, *, where: str,
                     backend: str | None = None) -> None:
    """Raise unless the persisted bundle file verifies structurally.

    Deliberately runs *before* any sha256 comparison at the load
    boundary: a truncated or hand-edited npz is refused on structure,
    so the integrity check never has to parse attacker-shaped bytes."""
    if enabled():
        _require(verify_bundle_file(path, backend=backend), where)


def iter_device_plans(tree: Any, path: tuple = ()
                      ) -> Iterator[tuple[str, Any]]:
    """Yield ``("a/b/dplan", DevicePlan)`` for every device plan
    embedded in a params pytree (dict/list/tuple walk — DevicePlan is a
    registered pytree, so ``jax.tree`` flattening would dissolve it)."""
    from repro.core.engine import DevicePlan
    if isinstance(tree, DevicePlan):
        yield "/".join(map(str, path)) or "dplan", tree
    elif isinstance(tree, dict):
        for k, v in tree.items():
            yield from iter_device_plans(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_device_plans(v, path + (i,))


def gate_params(params: Any, *, where: str) -> None:
    """Verify every DevicePlan embedded in a params pytree (the
    swap-staging gate: a hot-swap generation's plans are checked before
    they can be staged)."""
    if not enabled():
        return
    for label, dplan in iter_device_plans(params):
        findings = verify_device_plan(dplan, name=label)
        _require(findings, where)


# ---------------------------------------------------------------------------
# The --plans lint driver (CLI half; see analysis/lint.py)
# ---------------------------------------------------------------------------

def lint_plans(backend_names: list[str], *, mesh: Any = None
               ) -> tuple[list[dict], list[Finding]]:
    """Build representative plan artifacts per backend and verify them.

    Per planned backend: an ungrouped plan, a grouped plan, a stacked
    pair (``compile_plans``), a padded device plan, and a full
    save→``verify_bundle_file`` npz round trip (with the device
    lowering and weight fingerprint riding along). Device-resident
    backends verify their own ``compile`` hook's output; under a mesh
    the device plan is sharded first, so the verifier reads the same
    distributed leaves the serve path would. Returns (report rows,
    findings) — zero findings on a healthy tree.
    """
    from repro.core.backend import get_backend, shard_device_plan
    from repro.core.engine import (BatchedTransitiveEngine, compile_plans,
                                   pad_device_plan)
    from repro.core.plancache import weight_fingerprint

    report, all_findings = [], []
    rng = np.random.default_rng(7)
    for name in backend_names:
        b = get_backend(name)
        row = {"backend": name, "artifacts": [], "findings": []}
        if not b.needs_plan:
            row["skipped"] = "backend plans nothing (needs_plan=False)"
            report.append(row)
            continue
        eng = BatchedTransitiveEngine(bits=8, t=4)
        w = rng.integers(-128, 128, (16, 32)).astype(np.int64)
        w2 = rng.integers(-128, 128, (16, 32)).astype(np.int64)
        plan = eng.plan(w)
        grouped = eng.plan(w, groups=2)
        findings = []
        artifacts = [("plan", lambda: verify_plan(plan, backend=name)),
                     ("plan-grouped",
                      lambda: verify_plan(grouped, backend=name))]
        device = None
        if b.device_resident:
            device = b.compile(plan)
            if mesh is not None:
                device = shard_device_plan(device, mesh)
            stacked = compile_plans([plan, eng.plan(w2)])
            padded = pad_device_plan(
                device, int(np.asarray(device.direct_idx).shape[-1]) + 3)
            artifacts += [
                ("device", lambda: verify_device_plan(
                    device, plan, backend=name)),
                ("device-stacked", lambda: verify_device_plan(
                    stacked, backend=name, name="device-stacked")),
                ("device-padded", lambda: verify_device_plan(
                    padded, backend=name, name="device-padded")),
            ]

        def _roundtrip() -> list[Finding]:
            with tempfile.TemporaryDirectory() as td:
                p = os.path.join(td, "layer.npz")
                plan.save(p, device=device,
                          backend=name if device is not None else None,
                          fingerprint=weight_fingerprint(w))
                return verify_bundle_file(p, backend=name)

        artifacts.append(("bundle-roundtrip", _roundtrip))
        for label, fn in artifacts:
            fs = fn()
            findings.extend(fs)
            row["artifacts"].append(label)
        row["findings"] = [f.to_json() for f in findings]
        all_findings.extend(findings)
        report.append(row)
    return report, all_findings
