"""Tracelint rules: the serving-path invariants as registered objects.

The repo's performance story rests on *structural* properties of the
lowered programs — the paper's premise that the computation's structure
(the transitive DAG, its execution order) is analyzable ahead of time.
Each property is one :class:`Rule` in a process-level registry mirroring
``core/backend.py``'s style (``register_rule`` / ``get_rule`` /
``list_rules``): serving, CI and tests enumerate rules instead of
hardcoding assertion lists, and a new invariant drops in without touching
the driver.

A rule inspects one :class:`LintProgram` — a traced jaxpr plus, when the
check needs them, the lowered StableHLO text (buffer donation is only
visible there), the live arrays a program ran on (shardings are only
visible there), and the mesh. Every violation is a :class:`Finding`
carrying the offending primitive, the equation path inside the (possibly
deeply nested) jaxpr, and a severity; findings key into an allowlist
baseline (``analysis/baseline.py``) so new violations fail while known
ones stay explicit.

Built-in rules:

``no-host-callback``
    no ``pure_callback`` / ``io_callback`` / ``debug_callback`` anywhere
    in a serving program — a host round-trip per decode step is the
    failure mode PR 3 retired.
``gather-only-levels``
    no scatter-family primitive inside a ``scan``/``while`` body — the
    DevicePlan level loops advance by gathers only (the one legal scatter,
    direct dispatch, runs once per call *outside* the loop).
``static-shapes``
    every equation's output shape is a concrete integer tuple, and no
    ``while`` loops (data-dependent trip counts make the execution
    schedule no longer signature-determined).
``kv-donation``
    the decode jit really aliases its KV cache buffers — read from the
    lowered HLO's input-output aliasing, not from the donation *request*
    (which lowering may silently drop).
``dtype-purity``
    no bf16/f16 intermediates inside quantize subgraphs (the PR-6 KV8
    divergence class: a bf16 scale rounds differently depending on XLA
    fusion), and no float64 anywhere (silent x64/weak-type promotion).
``sharding-integrity``
    under a multi-device mesh, no large array the program materialised is
    silently fully replicated — the runtime twin of
    ``ShardingDropWarning``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np
import jax

from repro.analysis.walker import (CALLBACK_PRIMS, SCATTER_PRIMS,
                                   iter_eqns)

__all__ = ["Finding", "LintProgram", "Rule", "register_rule",
           "unregister_rule", "get_rule", "list_rules", "run_rules"]


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation, locatable and baselinable."""
    rule: str
    severity: str                 # "error" | "warning"
    program: str                  # "decode", "prefill", "forest", ...
    backend: str | None
    path: str                     # equation path ("" = program-level)
    primitive: str | None
    message: str

    def key(self) -> str:
        """Baseline key: stable across unrelated jaxpr edits (no equation
        path — the path is for humans, the key is for the allowlist)."""
        return "::".join((self.rule, self.backend or "-", self.program,
                          self.primitive or "-"))

    def format(self) -> str:
        where = f" at {self.path}" if self.path else ""
        return (f"[{self.severity}] {self.rule} ({self.program}"
                f"{', backend=' + self.backend if self.backend else ''})"
                f"{where}: {self.message}")

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["key"] = self.key()
        return d


@dataclasses.dataclass
class LintProgram:
    """One lintable serving program with everything rules may inspect.

    ``jaxpr`` feeds the structural rules; ``lowered_text`` (StableHLO,
    from ``jit(...).lower(...).as_text()``) feeds ``kv-donation``;
    ``arrays`` (label -> pytree of live arrays) + ``mesh`` feed
    ``sharding-integrity``. ``donate_expect`` maps a label to the
    ``[start, stop)`` range of flattened argument indices whose buffers
    the program promises to donate. ``rules`` names the rules this
    program is subject to — the driver intersects it with the backend's
    ``lint_exempt`` tags (core/backend.py).
    """
    name: str
    rules: tuple[str, ...]
    backend: str | None = None
    jaxpr: Any = None                                   # ClosedJaxpr
    lowered_text: str | None = None
    donate_expect: dict[str, tuple[int, int]] | None = None
    mesh: Any = None
    arrays: dict[str, Any] | None = None
    quantize_scopes: tuple[str, ...] = ("quantize_kv",)


class Rule:
    """Base class for one serving-path invariant.

    ``requires`` declares which :class:`LintProgram` field the rule reads
    (``"jaxpr"``, ``"lowered_text"`` or ``"arrays"``); the driver skips
    the rule with no finding when a program does not carry that evidence
    (e.g. no mesh -> no sharding check) — absence of evidence is a
    program-construction concern, not a violation.
    """
    name: str = ""
    severity: str = "error"
    requires: str = "jaxpr"
    description: str = ""

    def check(self, prog: LintProgram) -> list[Finding]:
        raise NotImplementedError

    def _finding(self, prog: LintProgram, message: str, *,
                 path: str = "", primitive: str | None = None) -> Finding:
        return Finding(rule=self.name, severity=self.severity,
                       program=prog.name, backend=prog.backend,
                       path=path, primitive=primitive, message=message)

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(name={self.name!r}, "
                f"severity={self.severity!r}, requires={self.requires!r})")


# ---------------------------------------------------------------------------
# Registry (core/backend.py's shape: loud duplicates, listed unknowns)
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Rule] = {}


def register_rule(rule: Rule, *, replace: bool = False) -> Rule:
    name = getattr(rule, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError(f"rule must declare a non-empty string name, "
                         f"got {name!r}")
    if name in _REGISTRY and not replace:
        raise ValueError(f"rule '{name}' is already registered "
                         f"({_REGISTRY[name]!r}); pass replace=True to "
                         f"override")
    _REGISTRY[name] = rule
    return rule


def unregister_rule(name: str) -> Rule:
    if name not in _REGISTRY:
        raise KeyError(_unknown_msg(name))
    return _REGISTRY.pop(name)


def list_rules() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def _unknown_msg(name) -> str:
    return (f"unknown rule {name!r}; registered rules: "
            f"{', '.join(sorted(_REGISTRY))}")


def get_rule(name: str) -> Rule:
    try:
        return _REGISTRY[name]
    except (KeyError, TypeError):
        raise KeyError(_unknown_msg(name)) from None


def run_rules(prog: LintProgram, *, exempt: frozenset[str] = frozenset(),
              only: tuple[str, ...] | None = None) -> list[Finding]:
    """Run every rule named in ``prog.rules`` (minus ``exempt``, and
    intersected with ``only`` when given) that has its required evidence."""
    out: list[Finding] = []
    for name in prog.rules:
        if name in exempt or (only is not None and name not in only):
            continue
        rule = get_rule(name)
        if getattr(prog, rule.requires, None) is None:
            continue
        out.extend(rule.check(prog))
    return out


# ---------------------------------------------------------------------------
# Built-in rules
# ---------------------------------------------------------------------------

class NoHostCallback(Rule):
    """Serving programs lower with zero host callbacks."""
    name = "no-host-callback"
    description = ("no pure_callback / io_callback / debug_callback in the "
                   "lowered program (PR 3 retired the callback hot path)")

    def check(self, prog):
        out = []
        for site in iter_eqns(prog.jaxpr):
            if site.primitive in CALLBACK_PRIMS:
                cb = site.eqn.params.get("callback")
                detail = f" ({cb})" if cb is not None else ""
                out.append(self._finding(
                    prog, f"host callback '{site.primitive}'{detail} in a "
                    f"serving program — decode/prefill must stay on "
                    f"device", path=site.path, primitive=site.primitive))
        return out


class GatherOnlyLevels(Rule):
    """DevicePlan level loops advance by gathers only."""
    name = "gather-only-levels"
    description = ("no scatter-family primitive inside a scan/while body; "
                   "the forest's one legal scatter (direct dispatch) runs "
                   "once per call outside the level loop")

    def check(self, prog):
        out = []
        for site in iter_eqns(prog.jaxpr):
            if site.primitive in SCATTER_PRIMS and site.in_loop:
                out.append(self._finding(
                    prog, f"'{site.primitive}' inside a loop body — level "
                    f"loops must be gather-only (psum[src] + x[xsrc]); a "
                    f"scatter per level serializes the forest",
                    path=site.path, primitive=site.primitive))
        return out


class StaticShapes(Rule):
    """Shapes (and the execution schedule) are signature-determined."""
    name = "static-shapes"
    description = ("every output shape is a concrete int tuple and there "
                   "are no while loops (data-dependent trip counts)")

    def check(self, prog):
        out = []
        for site in iter_eqns(prog.jaxpr):
            if site.primitive == "while":
                out.append(self._finding(
                    prog, "'while' loop: trip count is data-dependent, so "
                    "the execution schedule is no longer a pure function "
                    "of the input signature (use a bounded lax.scan)",
                    path=site.path, primitive="while"))
            for v in site.eqn.outvars:
                shape = getattr(v.aval, "shape", ())
                bad = [d for d in shape
                       if not isinstance(d, (int, np.integer))]
                if bad:
                    out.append(self._finding(
                        prog, f"dynamic dimension(s) {bad} in output aval "
                        f"{v.aval} — shapes must be signature-determined",
                        path=site.path, primitive=site.primitive))
        return out


# one %argN declaration with its attribute dict in StableHLO text
_ARG_RE = re.compile(r"%arg(\d+): tensor<[^>]*>\s*(\{[^}]*\})?")


def aliased_args(lowered_text: str) -> set[int]:
    """Flattened argument indices the lowered module marks as donated —
    the lowering-level truth about donation.

    Single-device lowering aliases each donated input to a concrete
    output (``tf.aliasing_output = N``); under a mesh the pairing is
    deferred to the compiler and the input carries ``jax.buffer_donor``
    instead. Either marker means the buffer is really donated.
    """
    return {int(m.group(1)) for m in _ARG_RE.finditer(lowered_text)
            if m.group(2) and ("tf.aliasing_output" in m.group(2)
                               or "jax.buffer_donor" in m.group(2))}


class KvDonation(Rule):
    """Decode really donates its KV cache buffers."""
    name = "kv-donation"
    requires = "lowered_text"
    description = ("the decode jit's lowered HLO aliases every KV-cache "
                   "input buffer to an output (donate_argnums that "
                   "lowering dropped = a full cache copy per token)")

    def check(self, prog):
        if not prog.donate_expect:
            return []
        got = aliased_args(prog.lowered_text)
        out = []
        for label, (start, stop) in prog.donate_expect.items():
            missing = sorted(set(range(start, stop)) - got)
            if missing:
                out.append(self._finding(
                    prog, f"{len(missing)}/{stop - start} {label} buffers "
                    f"are NOT aliased in the lowered HLO (flat arg indices "
                    f"{missing}) — every decode step pays a full copy of "
                    f"those buffers", path=label))
        return out


class DtypePurity(Rule):
    """Quantize subgraphs stay in f32/int; nothing promotes to f64."""
    name = "dtype-purity"
    description = ("no bf16/f16 intermediates inside quantize scopes "
                   "(jax.named_scope'd, e.g. _quantize_kv — the PR-6 KV8 "
                   "divergence class) and no float64 anywhere")

    def check(self, prog):
        out = []
        scopes = frozenset(prog.quantize_scopes)
        for site in iter_eqns(prog.jaxpr):
            for v in site.eqn.outvars:
                dt = getattr(v.aval, "dtype", None)
                if dt is None:
                    continue
                if str(dt) == "float64":
                    out.append(self._finding(
                        prog, f"float64 output aval {v.aval} — silent "
                        f"x64/weak-type promotion in a serving program",
                        path=site.path, primitive=site.primitive))
                elif str(dt) in ("bfloat16", "float16") \
                        and site.scopes & scopes:
                    scope = ", ".join(sorted(site.scopes & scopes))
                    out.append(self._finding(
                        prog, f"{dt} intermediate inside quantize scope "
                        f"'{scope}' — quantization arithmetic must run in "
                        f"f32 or the stored (int8, scale) pair becomes "
                        f"XLA-fusion-dependent (the PR-6 KV8 divergence)",
                        path=site.path, primitive=site.primitive))
        return out


class ShardingIntegrity(Rule):
    """No silent full replication of large arrays under a mesh."""
    name = "sharding-integrity"
    requires = "arrays"
    description = ("under a multi-device mesh, large arrays a program "
                   "materialised (KV caches) must not be fully replicated "
                   "— the runtime twin of ShardingDropWarning")
    min_bytes: int = 1024

    def _mesh_devices(self, mesh) -> int:
        shape = getattr(mesh, "shape", None)
        if shape is None:
            return 1
        n = 1
        for v in dict(shape).values():
            n *= int(v)
        return n

    def check(self, prog):
        if prog.mesh is None or self._mesh_devices(prog.mesh) <= 1:
            return []        # nothing to shard over
        out = []
        for label, tree in (prog.arrays or {}).items():
            leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
            for path, leaf in leaves:
                sharding = getattr(leaf, "sharding", None)
                if sharding is None:
                    continue
                nbytes = getattr(
                    leaf, "nbytes",
                    int(np.prod(getattr(leaf, "shape", ()) or (1,))))
                if nbytes < self.min_bytes:
                    continue
                if sharding.is_fully_replicated:
                    where = label + jax.tree_util.keystr(path)
                    out.append(self._finding(
                        prog, f"array '{where}' "
                        f"{tuple(getattr(leaf, 'shape', ()))} "
                        f"({nbytes} bytes) is fully replicated on a "
                        f"{self._mesh_devices(prog.mesh)}-device mesh — "
                        f"a dropped sharding multiplies memory and wastes "
                        f"every device but one", path=where))
        return out


for _r in (NoHostCallback(), GatherOnlyLevels(), StaticShapes(),
           KvDonation(), DtypePurity(), ShardingIntegrity()):
    register_rule(_r)
del _r
