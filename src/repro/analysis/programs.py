"""Build the lintable serving programs for one registered backend.

For a backend name from ``repro.core.backend.list_backends()`` this
module constructs the same programs the serve path runs — prefill, the
donated decode step, the paged (continuous-batching) decode step, and the
backend's forest execution — as :class:`~repro.analysis.rules.LintProgram`
objects: traced jaxprs, the decode steps' lowered StableHLO (donation is
only visible there), and, under a mesh, the live KV cache arrays a real
prefill produced (shardings are only visible there).

Program construction is capability-driven off the registry, so the lint
CLI holds for every backend ``list_backends()`` ever returns: a future
``engine_tpu`` gets the same program set the day it registers, and its
``lint_exempt`` tags (core/backend.py) opt it out of exactly the rules
that do not apply to it.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.analysis.rules import Finding, LintProgram, run_rules
from repro.core.backend import EngineConfig, get_backend

__all__ = ["build_programs", "lint_backend", "PROGRAM_RULES"]

# which rules guard which program (minus per-backend lint_exempt tags)
PROGRAM_RULES = {
    "prefill": ("no-host-callback", "static-shapes", "dtype-purity"),
    "decode": ("no-host-callback", "static-shapes", "dtype-purity",
               "kv-donation", "sharding-integrity"),
    "paged-decode": ("no-host-callback", "static-shapes", "dtype-purity",
                     "kv-donation"),
    # the post-hot-swap decode (PR 9): the same paged step on a SECOND
    # weight generation built off-thread by repro.fleet.build_generation
    # and pad-aligned against the first — swap must not cost the serving
    # invariants (kv-donation in particular stays finding-free)
    "paged-decode-swapped": ("no-host-callback", "static-shapes",
                             "dtype-purity", "kv-donation"),
    # the PR-8 fast paths: the Pallas live-page decode kernel and the
    # bucketed batched prefill are held to the same serving invariants as
    # the oracle paths they shadow, from day one
    "paged-attention": ("no-host-callback", "static-shapes", "dtype-purity",
                        "kv-donation"),
    "prefill-bucketed": ("no-host-callback", "static-shapes",
                         "dtype-purity"),
    "forest": ("gather-only-levels", "no-host-callback", "static-shapes"),
}


def _n_leaves(tree) -> int:
    return len(jax.tree_util.tree_leaves(tree))


def _lower_donated(fn, donate_argnums, *args) -> str:
    """Lowered StableHLO text with donation requested and unused args kept
    (pruning would shift the flat argument indices the donation rule
    checks against)."""
    return jax.jit(fn, donate_argnums=donate_argnums,
                   keep_unused=True).lower(*args).as_text()


def build_programs(backend_name: str, *, mesh=None, arch: str = "smollm-135m",
                   n_layers: int = 2, batch: int = 4, prompt_len: int = 8,
                   max_len: int = 16, page_size: int = 4,
                   w_bits: int = 4) -> list[LintProgram]:
    """The lintable program set for ``backend_name``.

    With ``mesh=`` (total size > 1) the decode program is built under the
    ambient mesh on a really-prefilled, batch-placed cache so the
    ``sharding-integrity`` rule sees live shardings; ``batch`` should
    divide the mesh's data extent or the lint will (correctly) report the
    replication drop.
    """
    from repro.configs import get_reduced
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.train.serve_step import (_jit_prefill, _place_batch,
                                        make_decode_step)

    backend = get_backend(backend_name)
    cfg = serve_config(get_reduced(arch).replace(n_layers=n_layers),
                       w_bits=w_bits, backend=backend_name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    params = model.attach_device_plans(params, mesh=mesh)
    batch_d = {"tokens": jax.random.randint(
        jax.random.PRNGKey(1), (batch, prompt_len), 0, cfg.vocab,
        jnp.int32)}
    ctx = jax_compat.set_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()
    n_params = _n_leaves(params)
    progs: list[LintProgram] = []

    with ctx:
        # -- prefill -------------------------------------------------------
        prefill_fn = lambda p, b: model.prefill(p, b, max_len)  # noqa: E731
        progs.append(LintProgram(
            name="prefill", backend=backend_name,
            rules=PROGRAM_RULES["prefill"],
            jaxpr=jax.make_jaxpr(prefill_fn)(params, batch_d)))

        # -- decode (donated; under a mesh: on live prefilled caches) ------
        if mesh is not None:
            placed = _place_batch(batch_d, mesh)
            _, caches = _jit_prefill(model, max_len, mesh)(params, placed)
            arrays = {"kv-cache": caches}
        else:
            caches, arrays = model.init_cache(batch, max_len), None
        tok = jnp.zeros((batch, 1), jnp.int32)
        step = jnp.int32(prompt_len)
        decode_fn = make_decode_step(model)
        progs.append(LintProgram(
            name="decode", backend=backend_name,
            rules=PROGRAM_RULES["decode"],
            jaxpr=jax.make_jaxpr(decode_fn)(params, caches, tok, step),
            lowered_text=_lower_donated(decode_fn, (1,), params, caches,
                                        tok, step),
            donate_expect={"kv-cache": (n_params,
                                        n_params + _n_leaves(caches))},
            mesh=mesh, arrays=arrays))

        # -- paged decode (the continuous-batching step) -------------------
        if model.supports_paged() is None:
            pages_per_slot = max_len // page_size
            pool = model.init_page_pool(batch * pages_per_slot + 1,
                                        page_size)
            page_idx = jnp.zeros((batch, pages_per_slot), jnp.int32)
            steps = jnp.zeros((batch,), jnp.int32)
            progs.append(LintProgram(
                name="paged-decode", backend=backend_name,
                rules=PROGRAM_RULES["paged-decode"],
                jaxpr=jax.make_jaxpr(model.decode_step_paged)(
                    params, pool, tok, page_idx, steps),
                lowered_text=_lower_donated(
                    model.decode_step_paged, (1,), params, pool, tok,
                    page_idx, steps),
                donate_expect={"kv-page-pool":
                               (n_params, n_params + _n_leaves(pool))}))

            # -- paged decode after a hot swap (second weight generation) --
            from repro.fleet import build_generation
            gen = build_generation(
                model, model.init(jax.random.PRNGKey(2)), ref=params,
                gen=1, mesh=mesh)
            n_swapped = _n_leaves(gen.params)
            progs.append(LintProgram(
                name="paged-decode-swapped", backend=backend_name,
                rules=PROGRAM_RULES["paged-decode-swapped"],
                jaxpr=jax.make_jaxpr(model.decode_step_paged)(
                    gen.params, pool, tok, page_idx, steps),
                lowered_text=_lower_donated(
                    model.decode_step_paged, (1,), gen.params, pool, tok,
                    page_idx, steps),
                donate_expect={"kv-page-pool":
                               (n_swapped, n_swapped + _n_leaves(pool))}))

            # -- paged decode through the Pallas live-page kernel ----------
            kernel_fn = lambda p, pl, t, pi, st: \
                model.decode_step_paged(p, pl, t, pi, st,
                                        kernel=True)  # noqa: E731
            progs.append(LintProgram(
                name="paged-attention", backend=backend_name,
                rules=PROGRAM_RULES["paged-attention"],
                jaxpr=jax.make_jaxpr(kernel_fn)(
                    params, pool, tok, page_idx, steps),
                lowered_text=_lower_donated(
                    kernel_fn, (1,), params, pool, tok, page_idx, steps),
                donate_expect={"kv-page-pool":
                               (n_params, n_params + _n_leaves(pool))}))

            # -- bucketed batched prefill (one padded bucket shape) --------
            lb = max(page_size, 8)
            b_tokens = jnp.zeros((batch, lb), jnp.int32)
            b_prefix = jnp.zeros((batch, 0), jnp.int32)
            b_plens = jnp.zeros((batch,), jnp.int32)
            b_slens = jnp.full((batch,), lb, jnp.int32)
            b_wp = jnp.zeros((batch, lb), jnp.int32)
            b_wo = jnp.zeros((batch, lb), jnp.int32)
            b_wpos = jnp.zeros((batch, lb), jnp.int32)
            bucketed_fn = lambda p, t, pl, *ix: \
                model.prefill_paged_batched(
                    p, t, pl, prefix_page_ids=ix[0], prefix_lens=ix[1],
                    suffix_lens=ix[2], write_page_ids=ix[3],
                    write_offs=ix[4], write_pos=ix[5])  # noqa: E731
            progs.append(LintProgram(
                name="prefill-bucketed", backend=backend_name,
                rules=PROGRAM_RULES["prefill-bucketed"],
                jaxpr=jax.make_jaxpr(bucketed_fn)(
                    params, b_tokens, pool, b_prefix, b_plens, b_slens,
                    b_wp, b_wo, b_wpos)))

        # -- forest (the DevicePlan level loops, per device backend) -------
        if backend.needs_plan and backend.device_resident:
            import numpy as np
            rng = np.random.default_rng(0)
            w = rng.integers(-8, 8, size=(5, 32))
            ecfg = EngineConfig(w_bits=4, t=8, groups=1)
            plan = backend.plan(w, ecfg)
            dplan = backend.compile(plan)
            qw = jnp.asarray(w, jnp.int8)
            x = jnp.asarray(rng.integers(-128, 128, size=(3, 32)),
                            jnp.int8)
            progs.append(LintProgram(
                name="forest", backend=backend_name,
                rules=PROGRAM_RULES["forest"],
                jaxpr=jax.make_jaxpr(
                    lambda xx: backend.execute(xx, qw, plan, dplan,
                                               ecfg))(x)))
    return progs


def lint_backend(backend_name: str, *, mesh=None,
                 only: tuple[str, ...] | None = None,
                 **build_kw) -> tuple[list[LintProgram], list[Finding]]:
    """Build and lint one backend's program set.

    Returns (programs, findings); the backend's ``lint_exempt`` tags are
    honored, ``only`` restricts to a rule subset (CLI ``--rules``).
    """
    backend = get_backend(backend_name)
    progs = build_programs(backend_name, mesh=mesh, **build_kw)
    findings: list[Finding] = []
    exempt = frozenset(getattr(backend, "lint_exempt", ()))
    for prog in progs:
        findings.extend(run_rules(prog, exempt=exempt, only=only))
    return progs, findings
