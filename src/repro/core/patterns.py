"""Computation-pattern classification and density statistics (Sec. 5.2, Fig. 9).

Four patterns per TransRow / node:
  ZR — Zero Row:          value 0, skipped entirely.
  FR — Full Result Reuse: a later duplicate of an already-computed node
                          (no PPE, one APE accumulation).
  PR — Prefix Result Reuse: first TransRow of a present node
                          (one PPE add from its prefix + one APE accumulation).
  TR — Transitive Reuse:  a bridge node materialised by the backward pass
                          (one PPE add, no APE — it only relays).

Runtime density (what Fig. 9 plots and what bounds at 1/T) is
``max(PPE_ops, APE_ops) / dense_ops`` — the 3-stage pipeline's throughput is
set by its slowest stage, and APE performs exactly one accumulation per
nonzero TransRow, hence the 1/T floor ("at least one accumulation per T-bit
element").
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core import hasse
from repro.core.scoreboard import ScoreboardInfo

__all__ = ["TileStats", "tile_stats"]


@dataclasses.dataclass
class TileStats:
    """Per-tile operation statistics; every field is (tiles,) int64."""
    n_rows: int
    t: int
    zr: np.ndarray            # zero rows
    fr: np.ndarray            # duplicate rows (full reuse)
    pr: np.ndarray            # first rows of present nodes
    tr: np.ndarray            # bridge nodes
    outliers: np.ndarray      # outlier nodes (distance >= 4)
    ppe_ops: np.ndarray       # total prefix-chain adds
    ape_ops: np.ndarray       # total output accumulations (nonzero rows)
    dense_ops: np.ndarray     # n_rows * T
    bit_ops: np.ndarray       # total popcount (bit-sparsity baseline)
    ppe_cycles: np.ndarray    # max per-lane PPE ops (+ outlier tail)
    ape_cycles: np.ndarray    # max per-lane APE ops
    dist_hist: np.ndarray     # (tiles, 5): executed present nodes at distance 0..4+
                              #  (0 bucket unused; kept for alignment with paper)

    @property
    def density(self) -> np.ndarray:
        return np.maximum(self.ppe_ops, self.ape_ops) / self.dense_ops

    @property
    def density_ppe(self) -> np.ndarray:
        return self.ppe_ops / self.dense_ops

    @property
    def bit_density(self) -> np.ndarray:
        return self.bit_ops / self.dense_ops

    @property
    def cycles(self) -> np.ndarray:
        """Pipeline throughput cycles per sub-tile (critical stage)."""
        return np.maximum(self.ppe_cycles, self.ape_cycles)


def tile_stats(si: ScoreboardInfo) -> TileStats:
    """Derive TileStats from (dynamic) ScoreboardInfo."""
    t, size = si.t, 1 << si.t
    levels = hasse.levels(t)
    counts = si.counts.astype(np.int64)
    present = si.present
    executed = si.executed

    zr = counts[:, 0]
    nonzero_rows = si.n_rows - zr
    unique_present = present.sum(-1).astype(np.int64)
    fr = nonzero_rows - unique_present
    tr = si.bridge.sum(-1).astype(np.int64)
    out_nodes = si.outlier.sum(-1).astype(np.int64)
    pr = unique_present - out_nodes

    # Each executed (non-outlier) node costs one add from its relay prefix;
    # outliers are accumulated directly (popcount adds each).
    out_ops = (si.outlier * levels[None, :]).sum(-1).astype(np.int64)
    ppe_ops = executed.sum(-1).astype(np.int64) + out_ops
    ape_ops = nonzero_rows.astype(np.int64)

    # PPE lanes execute prefix trees serially (dependency chains) — max lane.
    # APE accumulations are crossbar-distributed across lanes (Sec. 4.4), so
    # the APE stage runs at ceil(nonzero_rows / T).
    ppe_cycles = si.wl_ppe.max(-1) + (out_ops + t - 1) // t
    ape_cycles = (ape_ops + t - 1) // t

    dist = si.distance
    hist = np.zeros((si.tiles, 5), dtype=np.int64)
    for d in range(1, 4):
        hist[:, d] = (present & (dist == d)).sum(-1)
    hist[:, 4] = (present & (dist >= 4)).sum(-1)

    bit_ops = (counts * levels[None, :]).sum(-1)
    dense = np.full(si.tiles, si.n_rows * t, dtype=np.int64)
    return TileStats(n_rows=si.n_rows, t=t, zr=zr, fr=fr, pr=pr, tr=tr,
                     outliers=out_nodes, ppe_ops=ppe_ops, ape_ops=ape_ops,
                     dense_ops=dense, bit_ops=bit_ops,
                     ppe_cycles=ppe_cycles, ape_cycles=ape_cycles,
                     dist_hist=hist)
