"""Lossless transitive GEMM — public entry points, engine-backed.

``transitive_gemm`` executes ``W @ X`` for an S-bit integer weight
``W (N, K)`` and integer input ``X (K, M)`` through the batched multi-tile
engine (core/engine.py): all ``K//T`` scoreboards are built in one call and
the Scoreboard forest is executed level-synchronously across tiles. It must
be **bit-exact** against ``W.astype(i64) @ X.astype(i64)`` — the paper's
lossless claim (Sec. 2.1).

The original row-at-a-time walker lives on as core/transitive_ref.py; it is
the oracle that this engine, the Pallas kernel (kernels/transitive_gemm.py)
and the quant integer-matmul path are all differentially tested against
(tests/test_engine.py, tests/test_transitive_lossless.py).
"""
from __future__ import annotations

import numpy as np

from repro.core.engine import BatchedTransitiveEngine
from repro.core.transitive_ref import execute_tile, transitive_gemm_ref

__all__ = ["transitive_gemm", "transitive_gemm_stats", "execute_tile",
           "transitive_gemm_ref"]


def transitive_gemm(w: np.ndarray, x: np.ndarray, bits: int, t: int,
                    max_distance: int = 4) -> np.ndarray:
    """Full transitive GEMM: int-S ``w (N, K)`` @ int ``x (K, M)`` → int64."""
    eng = BatchedTransitiveEngine(bits=bits, t=t, max_distance=max_distance)
    return eng(np.asarray(w), np.asarray(x))


def transitive_gemm_stats(w: np.ndarray, x: np.ndarray, bits: int, t: int):
    """transitive_gemm + op counts; returns (out, dict of totals).

    The op counts come straight off the plan's batched scoreboard — the
    plan and the executed result share one ScoreboardInfo.
    """
    from repro.core.patterns import tile_stats
    eng = BatchedTransitiveEngine(bits=bits, t=t)
    plan = eng.plan(np.asarray(w))
    st = tile_stats(plan.si)
    out = eng.run(plan, np.asarray(x))
    totals = {k_: int(getattr(st, k_).sum()) for k_ in
              ("ppe_ops", "ape_ops", "dense_ops", "bit_ops")}
    totals["density"] = max(totals["ppe_ops"], totals["ape_ops"]) / totals["dense_ops"]
    return out, totals
