"""Batched multi-tile transitive execution engine (lossless fast path).

The reference walker (core/transitive_ref.py) executes one k-tile and one
Hasse node at a time in Python loops. This engine runs the same forest —
bit-exactly — with three batched passes:

  1. **plan(w)**: bit-slice ``w`` into TransRows, then build *all* ``K//T``
     per-tile scoreboards in a single :func:`dynamic_scoreboard` call (it is
     already vectorised over a leading tiles axis). The forest edges are
     regrouped by Hamming level into flat (tile, node, prefix, diff-bit)
     index arrays. This mirrors the paper's offline TransRow packing: a
     plan depends only on the weights and is reused across activations.
  2. **run(plan, x)** — forest execution: one vectorised numpy step per
     Hamming level across all tiles simultaneously. Every executed node's
     selected prefix is a covering (one-bit-cleared) subset, so all nodes
     of level L depend only on level L-1 psums and can gather + scatter in
     one fancy-indexed assignment. Outliers (and any prefix-less node) are
     dispatched first via a direct subset-sum einsum.
  3. **APE shift-accumulate**: per bit plane, one gather of the (tiles,
     2^T, M) psum table at the TransRow indices and a sum over tiles,
     weighted by the 2's-complement plane signs — the einsum-style
     equivalent of the hardware's shifter + accumulator.

Bit-exactness vs ``w.astype(i64) @ x.astype(i64)`` and vs the reference
walker is enforced by tests/test_engine.py across random and adversarial
weight patterns.

**Device-resident plans.** :func:`compile_plan` lowers an
:class:`ExecutionPlan` to a :class:`DevicePlan` — a pytree of static-shape
int32 index arrays (gather-only per-level source maps, the direct-dispatch
indices, and the APE gather table). :func:`run_device` then executes the
whole forest as a fixed sequence of ``jnp`` gathers and adds with **no
host callback**, so the same code path jits, vmaps and scans. Because
plans of a given layer signature share leaf shapes
(:func:`compile_plans`), plans for scan-stacked block weights stack into
one leading axis and ride through ``lax.scan`` alongside the weights
themselves — this is what lets the serving hot path retire
``jax.pure_callback`` entirely (quant/qlinear.py ``path="engine_jit"``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitslice, hasse
from repro.core.scoreboard import (MAX_DISTANCE, ScoreboardInfo,
                                   dynamic_scoreboard)

__all__ = ["BatchedTransitiveEngine", "ExecutionPlan", "LevelStep",
           "DevicePlan", "PlanBundle", "BundleMismatchError",
           "DEVICE_DATA_FIELDS", "compile_plan", "compile_plans",
           "pad_device_plan", "forest_body", "run_device",
           "run_device_jit"]


# DevicePlan's array leaves, in one place: the pytree registration, the
# sharding hook (core/backend.py shard_device_plan) and the persistence
# bundle all agree on this list by construction.
DEVICE_DATA_FIELDS = ("level_src", "level_xsrc", "direct_idx",
                      "direct_x_idx", "direct_bits", "gather_idx", "signs")


class BundleMismatchError(ValueError):
    """A persisted plan bundle does not match what it is being attached to.

    Raised by :meth:`ExecutionPlan.load_bundle` (weight fingerprint or
    engine-config mismatch against the weights/config the caller is about
    to serve with) and by the fleet manifest loader
    (repro.fleet.bundles) for manifest-level refusals. A plan is a pure
    function of the weight bit-patterns, so a stale bundle silently
    computes the *old* weights' GEMM — this error makes that loud.
    ``force=True`` on the loading API is the explicit escape hatch."""


@dataclasses.dataclass(frozen=True)
class LevelStep:
    """All forest edges of one Hamming level, across every tile."""
    tile: np.ndarray      # (E,) int64 — tile index of each executed node
    node: np.ndarray      # (E,) int64 — the node being computed
    prefix: np.ndarray    # (E,) int64 — its covering prefix (level - 1)
    bit: np.ndarray       # (E,) int64 — the single differing bit index


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Weight-only execution schedule — reusable across activations."""
    t: int                     # TransRow width
    bits: int                  # weight bit width S
    n: int                     # output rows
    k: int                     # reduction length (all groups concatenated)
    rows: np.ndarray           # (S, N, J) int64 TransRow values (APE gather)
    si: ScoreboardInfo         # batched scoreboard over all J tiles
    steps: tuple[LevelStep, ...]   # level-synchronous schedule, level 1..T
    direct_tile: np.ndarray    # (D,) int64 — outlier / prefix-less nodes
    direct_node: np.ndarray    # (D,) int64
    direct_bits: np.ndarray    # (D, T) int64 {0,1} — their bit patterns
    signs: np.ndarray          # (S,) int64 2's-complement plane weights
    groups: int = 1            # G quantization groups along K (1 = ungrouped)

    @property
    def n_tiles(self) -> int:
        return self.k // self.t

    # -- persistence (npz) ------------------------------------------------
    def save(self, path, *, device=None, backend: str | None = None,
             fingerprint: str | None = None) -> None:
        """Serialize the full plan (schedule + scoreboard) to an ``.npz``.

        Everything is plain numpy, so a plan precompiled in one process can
        be loaded in another (or shipped to a serving fleet) without paying
        the scoreboard build again; :func:`ExecutionPlan.load` round-trips
        bit-exactly (tests/test_engine.py).

        With ``device=`` a compiled :class:`DevicePlan` (possibly stacked
        along leading axes) rides in the same file, tagged with the
        ``backend`` registry name that lowered it — so a cached lowering
        also round-trips across processes (:meth:`load_bundle`) instead of
        being re-done per process.

        ``fingerprint=`` stores the content hash of the weights this plan
        was built from (``repro.core.plancache.weight_fingerprint`` over
        the canonical int8 bytes) so :meth:`load_bundle` can refuse to
        attach the bundle to different weights."""
        extra = {}
        if backend is not None and device is None:
            raise ValueError(
                "backend= tags the persisted device lowering; pass "
                "device= as well (a backend tag alone would be dropped "
                "silently on load)")
        if fingerprint is not None:
            extra["weight_fp"] = np.array(fingerprint)
        if device is not None:
            extra["device_meta"] = np.array(
                [device.t, device.bits, device.n, device.k, device.groups],
                np.int64)
            extra["device_backend"] = np.array(backend or "")
            for f in DEVICE_DATA_FIELDS:
                extra[f"device_{f}"] = np.asarray(getattr(device, f))
        cat = (np.concatenate if self.steps else
               lambda _: np.zeros(0, np.int64))
        np.savez(
            path,
            **extra,
            meta=np.array([self.t, self.bits, self.n, self.k, self.groups,
                           self.si.t, self.si.n_rows], np.int64),
            rows=self.rows,
            steps_len=np.array([s.tile.size for s in self.steps], np.int64),
            steps_tile=cat([s.tile for s in self.steps]),
            steps_node=cat([s.node for s in self.steps]),
            steps_prefix=cat([s.prefix for s in self.steps]),
            steps_bit=cat([s.bit for s in self.steps]),
            direct_tile=self.direct_tile, direct_node=self.direct_node,
            direct_bits=self.direct_bits, signs=self.signs,
            si_counts=self.si.counts, si_exec_counts=self.si.exec_counts,
            si_bridge=self.si.bridge, si_distance=self.si.distance,
            si_prefix=self.si.prefix, si_lane=self.si.lane,
            si_outlier=self.si.outlier, si_wl_ppe=self.si.wl_ppe,
            si_wl_ape=self.si.wl_ape)

    @staticmethod
    def load(path) -> "ExecutionPlan":
        """Inverse of :meth:`save` — bit-exact reconstruction."""
        with np.load(path) as z:
            return ExecutionPlan._from_npz(z)

    @staticmethod
    def _from_npz(z) -> "ExecutionPlan":
        t, bits, n, k, groups, si_t, si_n_rows = (int(v) for v in z["meta"])
        lens = z["steps_len"]
        bounds = np.cumsum(lens)[:-1]
        fields = (np.split(z[f"steps_{f}"], bounds) if lens.size else []
                  for f in ("tile", "node", "prefix", "bit"))
        steps = tuple(LevelStep(tile=tl, node=nd, prefix=pre, bit=bit)
                      for tl, nd, pre, bit in zip(*fields))
        si = ScoreboardInfo(
            t=si_t, n_rows=si_n_rows, counts=z["si_counts"],
            exec_counts=z["si_exec_counts"], bridge=z["si_bridge"],
            distance=z["si_distance"], prefix=z["si_prefix"],
            lane=z["si_lane"], outlier=z["si_outlier"],
            wl_ppe=z["si_wl_ppe"], wl_ape=z["si_wl_ape"])
        return ExecutionPlan(t=t, bits=bits, n=n, k=k, rows=z["rows"],
                             si=si, steps=steps,
                             direct_tile=z["direct_tile"],
                             direct_node=z["direct_node"],
                             direct_bits=z["direct_bits"],
                             signs=z["signs"], groups=groups)

    @staticmethod
    def load_bundle(path, *, qw=None, cfg=None,
                    force: bool = False) -> "PlanBundle":
        """Load a plan plus — when the file carries one — its persisted
        device lowering and the backend registry name that produced it.
        Files written without ``device=`` load with ``device=None``.

        ``qw=`` (the weights the caller is about to attach the plan to)
        and ``cfg=`` (anything with ``w_bits`` / ``t`` / ``groups``, e.g.
        an ``EngineConfig``) opt into validation: the stored weight
        fingerprint must match ``qw``'s content hash and the plan
        signature must match ``cfg``, else :class:`BundleMismatchError`.
        A bundle written without ``fingerprint=`` cannot prove anything
        about its weights, so asking it to (``qw=`` on a fingerprint-less
        file) also refuses. ``force=True`` skips the fingerprint/config
        refusals (shape mismatches still raise — they could never run)."""
        with np.load(path) as z:
            plan = ExecutionPlan._from_npz(z)
            stored_fp = (str(z["weight_fp"]) if "weight_fp" in z.files
                         else None)
            if "device_meta" not in z.files:
                device, backend = None, None
            else:
                t, bits, n, k, groups = (int(v) for v in z["device_meta"])
                device = DevicePlan(  # jnp from the module tail import
                    t=t, bits=bits, n=n, k=k, groups=groups,
                    **{f: jnp.asarray(z[f"device_{f}"])
                       for f in DEVICE_DATA_FIELDS})
                backend = str(z["device_backend"]) or None
        if cfg is not None:
            got = (plan.bits, plan.t, plan.groups)
            want = (cfg.w_bits, cfg.t, cfg.groups)
            if got != want and not force:
                raise BundleMismatchError(
                    f"{path}: plan (bits, t, groups)={got} does not match "
                    f"the serving config {want}; pass force=True to "
                    f"attach anyway")
        if qw is not None:
            # shape first: a wrong-shaped plan could never run at all
            from repro.core.plancache import _canonical, weight_fingerprint
            qw_c = _canonical(np.asarray(qw))
            if qw_c.shape != (plan.n, plan.k):
                raise BundleMismatchError(
                    f"{path}: plan is for weights (n, k)=({plan.n}, "
                    f"{plan.k}), got {qw_c.shape}")
            if not force:
                if stored_fp is None:
                    raise BundleMismatchError(
                        f"{path}: bundle carries no weight fingerprint "
                        f"(written without fingerprint=), so it cannot be "
                        f"validated against these weights; pass "
                        f"force=True to attach anyway")
                fp = weight_fingerprint(qw_c)
                if fp != stored_fp:
                    raise BundleMismatchError(
                        f"{path}: bundle was planned from weights "
                        f"{stored_fp}, but these weights hash to {fp} — "
                        f"a stale plan would compute the old weights' "
                        f"GEMM; pass force=True to attach anyway")
        return PlanBundle(plan=plan, device=device, backend=backend,
                          fingerprint=stored_fp)


class BatchedTransitiveEngine:
    """Plan/run split over the whole (N, K) weight at once.

    ``plan`` is the offline half (scoreboards + schedule from weights);
    ``run`` is the online half (psums + shift-accumulate from activations).
    ``__call__`` chains both for one-shot use.
    """

    def __init__(self, bits: int, t: int, max_distance: int = MAX_DISTANCE):
        self.bits = bits
        self.t = t
        self.max_distance = max_distance

    # -- offline: weights -> reusable schedule ---------------------------
    def plan(self, w: np.ndarray, groups: int = 1) -> ExecutionPlan:
        """Build the weight-only schedule.

        With ``groups=G`` the columns of ``w`` are G concatenated
        quantization groups of ``K//G`` each; the scoreboard/forest build is
        identical (it is already batched over k-tiles), only :meth:`run`'s
        final reduction changes to keep one partial sum per group. This is
        how all G groups of a group-quantized layer plan as a *single*
        batched tile axis instead of G separate engine invocations.
        """
        w = np.asarray(w)
        n, k = w.shape
        t = self.t
        if k % t:
            raise ValueError(f"K={k} not divisible by T={t}")
        if groups < 1 or k % groups or (k // groups) % t:
            raise ValueError(
                f"K={k} not divisible into {groups} T={t}-aligned groups")
        rows = bitslice.transrow_matrix(w, self.bits, t).astype(np.int64)
        n_tiles = k // t
        tile_rows = rows.transpose(2, 0, 1).reshape(n_tiles, -1)  # (J, S*N)
        si = dynamic_scoreboard(tile_rows, t, self.max_distance)

        executed = si.executed                       # (J, 2^T) bool
        # Nodes executed without a relay prefix (shouldn't occur for a
        # healthy scoreboard beyond level 1 roots, which use node 0) plus
        # outliers are dispatched directly as subset sums of their bits.
        prefixless = executed & (si.prefix < 0)
        direct = si.outlier | prefixless
        chained = executed & ~prefixless

        node_levels = hasse.levels(t)[None, :]       # (1, 2^T)
        lsb_of = np.full(1 << t, -1, dtype=np.int64)
        lsb_of[1 << np.arange(t)] = np.arange(t)

        steps = []
        for lv in range(1, t + 1):
            tl, nd = np.nonzero(chained & (node_levels == lv))
            if tl.size == 0:
                continue
            pre = si.prefix[tl, nd]
            diff = nd ^ pre
            bit = lsb_of[diff]
            # the balanced forest only emits covering (distance-1) edges;
            # a -1 here would silently gather the wrong activation row, so
            # fail loudly even under python -O
            if not (bit >= 0).all():
                raise ValueError("non-covering edge in scoreboard forest")
            steps.append(LevelStep(tile=tl, node=nd.astype(np.int64),
                                   prefix=pre.astype(np.int64), bit=bit))

        d_tile, d_node = np.nonzero(direct)
        d_bits = ((d_node[:, None] >> np.arange(t)) & 1).astype(np.int64)
        return ExecutionPlan(t=t, bits=self.bits, n=n, k=k, rows=rows, si=si,
                             steps=tuple(steps),
                             direct_tile=d_tile.astype(np.int64),
                             direct_node=d_node.astype(np.int64),
                             direct_bits=d_bits,
                             signs=bitslice.plane_signs(self.bits),
                             groups=groups)

    # -- online: activations through the planned forest ------------------
    def run(self, plan: ExecutionPlan, x: np.ndarray) -> np.ndarray:
        """Execute the planned forest against activations ``x`` (K, M).

        Returns (N, M) for an ungrouped plan; (N, G, M) per-group partial
        sums for a grouped one (epilogue rescaling happens in the caller).
        """
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[0] != plan.k:
            raise ValueError(f"x must be (K={plan.k}, M), got {x.shape}")
        m = x.shape[1]
        t, n_tiles = plan.t, plan.n_tiles
        size = 1 << t
        xt = x.reshape(n_tiles, t, m).astype(np.int64)     # (J, T, M)

        psum = np.zeros((n_tiles, size, m), dtype=np.int64)
        if plan.direct_tile.size:
            psum[plan.direct_tile, plan.direct_node] = np.einsum(
                "dt,dtm->dm", plan.direct_bits, xt[plan.direct_tile])
        for step in plan.steps:        # level-synchronous forest execution
            psum[step.tile, step.node] = (psum[step.tile, step.prefix]
                                          + xt[step.tile, step.bit])

        # APE shift-accumulate: gather every TransRow's psum and reduce
        # over each group's tiles, one vectorised pass per bit plane.
        flat = psum.reshape(n_tiles * size, m)
        gather_idx = np.arange(n_tiles, dtype=np.int64)[None, None, :] * size \
            + plan.rows                                     # (S, N, J)
        g, jg = plan.groups, n_tiles // plan.groups
        out = np.zeros((plan.n, g, m), dtype=np.int64)
        for s in range(plan.bits):
            gathered = flat[gather_idx[s]].reshape(plan.n, g, jg, m)
            out += plan.signs[s] * gathered.sum(axis=2)
        return out[:, 0] if g == 1 else out

    def __call__(self, w: np.ndarray, x: np.ndarray) -> np.ndarray:
        return self.run(self.plan(w), x)


# ---------------------------------------------------------------------------
# Device-resident plans: the level-synchronous forest as pure JAX
# ---------------------------------------------------------------------------
#
# plan()/run() above are pure numpy, but importing this module requires
# jax from here down (DevicePlan pytree registration + the module-level
# jitted runner) — like every other serving-path module in the repo.
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True, eq=False)
class DevicePlan:
    """A compiled, device-resident execution schedule (pytree of int32).

    All index arrays are *flat*: the (tiles, 2^T, M) psum table of the host
    engine becomes one (J * 2^T, M) buffer, node ``v`` of tile ``j`` lives
    at row ``j * 2^T + v``, and activation row ``b`` of tile ``j`` at row
    ``j * T + b`` of the (K, M) input.

    The level-synchronous schedule is stored **gather-only**: instead of a
    ragged edge list that would scatter into the psum table (XLA scatters
    carry a large fixed cost per op, and ragged lists need cross-layer
    padding), each level holds a *complete* source map over all ``J * 2^T``
    rows — a row executed at this level gathers its covering prefix's psum
    plus one activation row; every other row gathers itself and a pinned
    zero activation row (index ``K``, one past the input). Each level is
    then two gathers and an add, the shapes depend only on the layer
    signature (never on weight content), and identically-shaped plans
    stack along a leading axis with no re-padding — the layout ``lax.scan``
    wants for scan-stacked block weights. Plans ride *inside the params*
    of a scanned model (core/plancache.attach_device_plans), so the
    serving hot path runs with zero host callbacks.

    The one remaining scatter (direct dispatch of outliers and prefix-less
    roots) happens once per call; its padding lanes target one-past-end
    rows and are discarded by ``mode="drop"``.
    """
    # static schedule signature (pytree aux data)
    t: int
    bits: int
    n: int
    k: int
    groups: int
    # gather-only level maps over the full flat psum table (R = J * 2^T)
    level_src: jnp.ndarray     # (T, R) int32 — psum row to gather (self if
    #                            the row is not executed at this level)
    level_xsrc: jnp.ndarray    # (T, R) int32 — activation row j*T+bit, or
    #                            K (the pinned zero row) for identity lanes
    # direct dispatch (outliers + prefix-less roots), padded to (D,)
    direct_idx: jnp.ndarray    # (D,) int32 — scatter target (pad: J*2^T)
    direct_x_idx: jnp.ndarray  # (D, T) int32 — activation rows (pad: 0)
    direct_bits: jnp.ndarray   # (D, T) int32 {0,1} — subset mask (pad: 0)
    # APE shift-accumulate
    gather_idx: jnp.ndarray    # (S, N, J) int32 — flat psum rows per TransRow
    signs: jnp.ndarray         # (S,) int32 — 2's-complement plane weights

    @property
    def n_tiles(self) -> int:
        return self.k // self.t


jax.tree_util.register_dataclass(
    DevicePlan,
    data_fields=list(DEVICE_DATA_FIELDS),
    meta_fields=["t", "bits", "n", "k", "groups"])


@dataclasses.dataclass(frozen=True)
class PlanBundle:
    """What :meth:`ExecutionPlan.load_bundle` returns: the host plan, and —
    when the file persisted one — its device lowering plus the backend
    registry name that produced it, and the fingerprint of the weights
    the plan was built from (None for pre-fingerprint files)."""
    plan: ExecutionPlan
    device: DevicePlan | None
    backend: str | None
    fingerprint: str | None = None


def compile_plan(plan: ExecutionPlan, *,
                 direct_pad: int | None = None) -> DevicePlan:
    """Lower an :class:`ExecutionPlan` to device-resident index arrays.

    ``direct_pad`` overrides the minimal direct-dispatch width so that
    plans of the same layer signature get identical leaf shapes — the
    precondition for stacking them (:func:`compile_plans`) and for sharing
    one jit trace across layers. The level maps are already
    signature-shaped.
    """
    t, size, j = plan.t, 1 << plan.t, plan.n_tiles
    invalid = j * size                       # one-past-end: dropped scatter
    r = j * size
    level_src = np.tile(np.arange(r, dtype=np.int32), (t, 1))
    level_xsrc = np.full((t, r), plan.k, np.int32)   # K = pinned zero row
    lvl_of = hasse.levels(t)
    for s in plan.steps:
        lv = int(lvl_of[int(s.node[0])])     # all nodes of a step share it
        rows = (s.tile * size + s.node).astype(np.int64)
        level_src[lv - 1, rows] = s.tile * size + s.prefix
        level_xsrc[lv - 1, rows] = s.tile * t + s.bit

    d_need = plan.direct_tile.size
    d = d_need if direct_pad is None else int(direct_pad)
    if d < d_need:
        raise ValueError(f"direct_pad={d} < direct nodes {d_need}")
    d = max(d, 1)
    direct_idx = np.full((d,), invalid, np.int32)
    direct_x_idx = np.zeros((d, t), np.int32)
    direct_bits = np.zeros((d, t), np.int32)
    if d_need:
        direct_idx[:d_need] = plan.direct_tile * size + plan.direct_node
        direct_x_idx[:d_need] = (plan.direct_tile[:, None] * t
                                 + np.arange(t, dtype=np.int64))
        direct_bits[:d_need] = plan.direct_bits

    gather_idx = (np.arange(j, dtype=np.int64)[None, None, :] * size
                  + plan.rows).astype(np.int32)
    return DevicePlan(
        t=t, bits=plan.bits, n=plan.n, k=plan.k, groups=plan.groups,
        level_src=jnp.asarray(level_src),
        level_xsrc=jnp.asarray(level_xsrc),
        direct_idx=jnp.asarray(direct_idx),
        direct_x_idx=jnp.asarray(direct_x_idx),
        direct_bits=jnp.asarray(direct_bits),
        gather_idx=jnp.asarray(gather_idx),
        signs=jnp.asarray(plan.signs.astype(np.int32)))


def compile_plans(plans) -> DevicePlan:
    """Compile several same-signature plans into ONE stacked DevicePlan.

    Pads every plan to the shared direct-dispatch bound (the level maps are
    signature-shaped already), then stacks each leaf along a new leading
    axis — the layout ``lax.scan`` wants for plans of scan-stacked block
    weights. Raises if signatures differ.
    """
    plans = list(plans)
    if not plans:
        raise ValueError("compile_plans needs at least one plan")
    sig = {(p.t, p.bits, p.n, p.k, p.groups) for p in plans}
    if len(sig) != 1:
        raise ValueError(f"cannot stack plans of differing signatures {sig}")
    d = max(p.direct_tile.size for p in plans)
    dps = [compile_plan(p, direct_pad=d) for p in plans]
    return jax.tree.map(lambda *leaves: jnp.stack(leaves), *dps)


def pad_device_plan(dplan: DevicePlan, direct_pad: int) -> DevicePlan:
    """Widen a compiled plan's direct-dispatch axis to ``direct_pad``.

    The pad lanes are the same bit-exact no-ops :func:`compile_plan`
    emits — scatter target ``J * 2^T`` (one past the table, discarded by
    ``mode="drop"``), activation row 0, all-zero bit mask — so the padded
    plan computes identical results. The point is aval stability: ``D``
    is a function of the *weight content*, so two generations of weights
    lower to different leaf shapes unless the later one is padded to at
    least the earlier one's width; the fleet layer
    (repro.fleet.replan.align_device_plans) uses this to keep the
    serve engine's memoised decode jit from retracing on a hot swap.
    Works on stacked plans too (leading axes are preserved)."""
    d = int(dplan.direct_idx.shape[-1])
    pad = int(direct_pad)
    if pad < d:
        raise ValueError(f"direct_pad={pad} < current width {d}")
    if pad == d:
        return dplan
    lead = tuple(dplan.direct_idx.shape[:-1])
    invalid = dplan.n_tiles * (1 << dplan.t)
    pad_idx = jnp.full(lead + (pad - d,), invalid,
                       dplan.direct_idx.dtype)
    pad_2d = jnp.zeros(lead + (pad - d, dplan.t),
                       dplan.direct_x_idx.dtype)
    return dataclasses.replace(
        dplan,
        direct_idx=jnp.concatenate([dplan.direct_idx, pad_idx], axis=-1),
        direct_x_idx=jnp.concatenate(
            [dplan.direct_x_idx, pad_2d], axis=-2),
        direct_bits=jnp.concatenate(
            [dplan.direct_bits,
             pad_2d.astype(dplan.direct_bits.dtype)], axis=-2))


def forest_body(xt, level_src, level_xsrc, direct_idx, direct_x_idx,
                direct_bits, gather_idx, signs, *, t: int, groups: int,
                n: int, k: int) -> jnp.ndarray:
    """The forest schedule on plain arrays: int32 xt (K, M) -> (N, G, M).

    The single pure-jnp body behind BOTH device backends —
    :func:`run_device` and the Pallas kernel
    (kernels/transitive_forest.py) pass the same DevicePlan leaves here,
    so their bit-exactness is shared code, not two hand-synced copies.
    """
    size = 1 << t
    j = k // t
    m = xt.shape[1]
    # pinned zero row at index K: identity lanes add nothing
    xt_ext = jnp.concatenate([xt, jnp.zeros((1, m), jnp.int32)])

    # direct dispatch: subset sums of each outlier/root pattern's bits
    contrib = (direct_bits[:, :, None]
               * xt[direct_x_idx]).sum(axis=1)             # (D, M)
    psum = jnp.zeros((j * size, m), jnp.int32)
    psum = psum.at[direct_idx].set(contrib, mode="drop")

    # level-synchronous forest, gather-only: every row advances as
    # psum[src] + x[xsrc]; non-executed rows gather themselves + zero
    def level(ps, edges):
        src, xsrc = edges
        return ps[src] + xt_ext[xsrc], None
    psum, _ = jax.lax.scan(level, psum, (level_src, level_xsrc))

    # APE shift-accumulate: gather every TransRow's psum, reduce per group
    s = signs.shape[0]
    jg = j // groups
    gathered = (psum[gather_idx.reshape(-1)]
                .reshape(s, n, groups, jg, m).sum(axis=3))    # (S, N, G, M)
    return (signs[:, None, None, None] * gathered).sum(axis=0)


def run_device(dplan: DevicePlan, x: jnp.ndarray) -> jnp.ndarray:
    """Execute a compiled forest against activations ``x`` (K, M) — pure jnp.

    Returns int32 (N, M) for an ungrouped plan, (N, G, M) per-group partials
    for a grouped one. Accumulates in int32, which is congruent mod 2^32
    with the host engine's int64 pipeline — i.e. bit-exact with the
    ``int_dot`` path's int32 accumulator. Composes with jit / vmap / scan;
    the lowered jaxpr contains no ``pure_callback``.
    """
    if x.ndim != 2 or x.shape[0] != dplan.k:
        raise ValueError(f"x must be (K={dplan.k}, M), got {x.shape}")
    out = forest_body(
        x.astype(jnp.int32), dplan.level_src, dplan.level_xsrc,
        dplan.direct_idx, dplan.direct_x_idx, dplan.direct_bits,
        dplan.gather_idx, dplan.signs, t=dplan.t, groups=dplan.groups,
        n=dplan.n, k=dplan.k)
    return out[:, 0] if dplan.groups == 1 else out


run_device_jit = jax.jit(run_device)
