"""Batched multi-tile transitive execution engine (lossless fast path).

The reference walker (core/transitive_ref.py) executes one k-tile and one
Hasse node at a time in Python loops. This engine runs the same forest —
bit-exactly — with three batched passes:

  1. **plan(w)**: bit-slice ``w`` into TransRows, then build *all* ``K//T``
     per-tile scoreboards in a single :func:`dynamic_scoreboard` call (it is
     already vectorised over a leading tiles axis). The forest edges are
     regrouped by Hamming level into flat (tile, node, prefix, diff-bit)
     index arrays. This mirrors the paper's offline TransRow packing: a
     plan depends only on the weights and is reused across activations.
  2. **run(plan, x)** — forest execution: one vectorised numpy step per
     Hamming level across all tiles simultaneously. Every executed node's
     selected prefix is a covering (one-bit-cleared) subset, so all nodes
     of level L depend only on level L-1 psums and can gather + scatter in
     one fancy-indexed assignment. Outliers (and any prefix-less node) are
     dispatched first via a direct subset-sum einsum.
  3. **APE shift-accumulate**: per bit plane, one gather of the (tiles,
     2^T, M) psum table at the TransRow indices and a sum over tiles,
     weighted by the 2's-complement plane signs — the einsum-style
     equivalent of the hardware's shifter + accumulator.

Bit-exactness vs ``w.astype(i64) @ x.astype(i64)`` and vs the reference
walker is enforced by tests/test_engine.py across random and adversarial
weight patterns.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import bitslice, hasse
from repro.core.scoreboard import (MAX_DISTANCE, ScoreboardInfo,
                                   dynamic_scoreboard)

__all__ = ["BatchedTransitiveEngine", "ExecutionPlan", "LevelStep"]


@dataclasses.dataclass(frozen=True)
class LevelStep:
    """All forest edges of one Hamming level, across every tile."""
    tile: np.ndarray      # (E,) int64 — tile index of each executed node
    node: np.ndarray      # (E,) int64 — the node being computed
    prefix: np.ndarray    # (E,) int64 — its covering prefix (level - 1)
    bit: np.ndarray       # (E,) int64 — the single differing bit index


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Weight-only execution schedule — reusable across activations."""
    t: int                     # TransRow width
    bits: int                  # weight bit width S
    n: int                     # output rows
    k: int                     # reduction length (all groups concatenated)
    rows: np.ndarray           # (S, N, J) int64 TransRow values (APE gather)
    si: ScoreboardInfo         # batched scoreboard over all J tiles
    steps: tuple[LevelStep, ...]   # level-synchronous schedule, level 1..T
    direct_tile: np.ndarray    # (D,) int64 — outlier / prefix-less nodes
    direct_node: np.ndarray    # (D,) int64
    direct_bits: np.ndarray    # (D, T) int64 {0,1} — their bit patterns
    signs: np.ndarray          # (S,) int64 2's-complement plane weights
    groups: int = 1            # G quantization groups along K (1 = ungrouped)

    @property
    def n_tiles(self) -> int:
        return self.k // self.t


class BatchedTransitiveEngine:
    """Plan/run split over the whole (N, K) weight at once.

    ``plan`` is the offline half (scoreboards + schedule from weights);
    ``run`` is the online half (psums + shift-accumulate from activations).
    ``__call__`` chains both for one-shot use.
    """

    def __init__(self, bits: int, t: int, max_distance: int = MAX_DISTANCE):
        self.bits = bits
        self.t = t
        self.max_distance = max_distance

    # -- offline: weights -> reusable schedule ---------------------------
    def plan(self, w: np.ndarray, groups: int = 1) -> ExecutionPlan:
        """Build the weight-only schedule.

        With ``groups=G`` the columns of ``w`` are G concatenated
        quantization groups of ``K//G`` each; the scoreboard/forest build is
        identical (it is already batched over k-tiles), only :meth:`run`'s
        final reduction changes to keep one partial sum per group. This is
        how all G groups of a group-quantized layer plan as a *single*
        batched tile axis instead of G separate engine invocations.
        """
        w = np.asarray(w)
        n, k = w.shape
        t = self.t
        if k % t:
            raise ValueError(f"K={k} not divisible by T={t}")
        if groups < 1 or k % groups or (k // groups) % t:
            raise ValueError(
                f"K={k} not divisible into {groups} T={t}-aligned groups")
        rows = bitslice.transrow_matrix(w, self.bits, t).astype(np.int64)
        n_tiles = k // t
        tile_rows = rows.transpose(2, 0, 1).reshape(n_tiles, -1)  # (J, S*N)
        si = dynamic_scoreboard(tile_rows, t, self.max_distance)

        executed = si.executed                       # (J, 2^T) bool
        # Nodes executed without a relay prefix (shouldn't occur for a
        # healthy scoreboard beyond level 1 roots, which use node 0) plus
        # outliers are dispatched directly as subset sums of their bits.
        prefixless = executed & (si.prefix < 0)
        direct = si.outlier | prefixless
        chained = executed & ~prefixless

        node_levels = hasse.levels(t)[None, :]       # (1, 2^T)
        lsb_of = np.full(1 << t, -1, dtype=np.int64)
        lsb_of[1 << np.arange(t)] = np.arange(t)

        steps = []
        for lv in range(1, t + 1):
            tl, nd = np.nonzero(chained & (node_levels == lv))
            if tl.size == 0:
                continue
            pre = si.prefix[tl, nd]
            diff = nd ^ pre
            bit = lsb_of[diff]
            # the balanced forest only emits covering (distance-1) edges;
            # a -1 here would silently gather the wrong activation row, so
            # fail loudly even under python -O
            if not (bit >= 0).all():
                raise ValueError("non-covering edge in scoreboard forest")
            steps.append(LevelStep(tile=tl, node=nd.astype(np.int64),
                                   prefix=pre.astype(np.int64), bit=bit))

        d_tile, d_node = np.nonzero(direct)
        d_bits = ((d_node[:, None] >> np.arange(t)) & 1).astype(np.int64)
        return ExecutionPlan(t=t, bits=self.bits, n=n, k=k, rows=rows, si=si,
                             steps=tuple(steps),
                             direct_tile=d_tile.astype(np.int64),
                             direct_node=d_node.astype(np.int64),
                             direct_bits=d_bits,
                             signs=bitslice.plane_signs(self.bits),
                             groups=groups)

    # -- online: activations through the planned forest ------------------
    def run(self, plan: ExecutionPlan, x: np.ndarray) -> np.ndarray:
        """Execute the planned forest against activations ``x`` (K, M).

        Returns (N, M) for an ungrouped plan; (N, G, M) per-group partial
        sums for a grouped one (epilogue rescaling happens in the caller).
        """
        x = np.asarray(x)
        if x.ndim != 2 or x.shape[0] != plan.k:
            raise ValueError(f"x must be (K={plan.k}, M), got {x.shape}")
        m = x.shape[1]
        t, n_tiles = plan.t, plan.n_tiles
        size = 1 << t
        xt = x.reshape(n_tiles, t, m).astype(np.int64)     # (J, T, M)

        psum = np.zeros((n_tiles, size, m), dtype=np.int64)
        if plan.direct_tile.size:
            psum[plan.direct_tile, plan.direct_node] = np.einsum(
                "dt,dtm->dm", plan.direct_bits, xt[plan.direct_tile])
        for step in plan.steps:        # level-synchronous forest execution
            psum[step.tile, step.node] = (psum[step.tile, step.prefix]
                                          + xt[step.tile, step.bit])

        # APE shift-accumulate: gather every TransRow's psum and reduce
        # over each group's tiles, one vectorised pass per bit plane.
        flat = psum.reshape(n_tiles * size, m)
        gather_idx = np.arange(n_tiles, dtype=np.int64)[None, None, :] * size \
            + plan.rows                                     # (S, N, J)
        g, jg = plan.groups, n_tiles // plan.groups
        out = np.zeros((plan.n, g, m), dtype=np.int64)
        for s in range(plan.bits):
            gathered = flat[gather_idx[s]].reshape(plan.n, g, jg, m)
            out += plan.signs[s] * gathered.sum(axis=2)
        return out[:, 0] if g == 1 else out

    def __call__(self, w: np.ndarray, x: np.ndarray) -> np.ndarray:
        return self.run(self.plan(w), x)
