"""Process-level ExecutionPlan cache for the serving path.

The paper's offline/online split (TransRow packing + Scoreboard build are
weight-only; only forest execution depends on activations) only pays off if
the offline half runs **once per weight**, not once per forward call. This
module is that amortisation, as a first-class subsystem:

  * :class:`PlanCache` — an LRU-bounded map from
    ``(weight fingerprint, EngineConfig)`` to a ready
    :class:`~repro.core.engine.ExecutionPlan`, with hit / miss / eviction /
    invalidation counters so serving can *prove* each plan was built exactly
    once (misses == distinct quantized weights, hits == remaining calls).
    Counters carry a **backend dimension**: lookups tagged with a registry
    backend name (core/backend.py) are attributed per backend in
    ``stats()["backends"]``, so a serve report can say which backend's hot
    path the hits came from.
  * a process-level default cache that the jit-side host callbacks of the
    ``engine`` backend consult on every forward — the hot path only ever
    executes ``run(plan, x)``.
  * :func:`precompile` — an offline pass that walks a model's params pytree
    (including vmap-stacked leading axes from scanned super-blocks) and
    builds every PTQ layer's plan up front, so the first decoded token pays
    zero plan-build cost.

Lookups take an :class:`~repro.core.backend.EngineConfig` (the loose
``(w_bits, t, groups)`` ints are still accepted as a legacy form). Weights
are fingerprinted by content (blake2b over shape/dtype/bytes), so a weight
*update* naturally misses — and :meth:`PlanCache.invalidate` drops the
stale entry explicitly so updated-weight serving does not leak plans until
LRU pressure finds them. Content keys make correctness unconditional (no
way to serve a stale plan) at the cost of hashing the int8 weight bytes per
lookup. Callers that manage their own weight identity (a layer id plus a
step counter, say) can pass ``version=`` instead: the tag becomes the
lookup key and the bytes are only hashed once, at build time, so
:meth:`invalidate` stays content-based and can still find version-keyed
entries when the weight updates.

Two plan representations live behind the same keys: the host-numpy
:class:`~repro.core.engine.ExecutionPlan` (built once per weight) and the
device-resident :class:`~repro.core.engine.DevicePlan` it lowers to
(:meth:`get_or_build_device`, compiled lazily from the cached host plan
through the requesting backend's ``compile`` hook).
:func:`attach_device_plans` embeds compiled plans *into a params pytree* —
stacked along any vmap/scan leading axes, optionally placed on a mesh with
``PartitionSpec``s — which is how the pure-JAX device backends
(quant/qlinear.py) see plans for weights that are tracers inside the
model's block scan.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Hashable, Iterator

import numpy as np

from repro.core.backend import (EngineConfig, TransitiveBackend,
                                get_backend, shard_device_plan)
from repro.core.engine import (BatchedTransitiveEngine, DevicePlan,
                               ExecutionPlan)

__all__ = ["PlanCache", "weight_fingerprint", "default_cache",
           "set_default_cache", "precompile", "attach_device_plans"]

# ("fp", content-hash, bits, t, groups) or ("v", version-tag, bits, t, groups)
PlanKey = tuple


@dataclasses.dataclass
class _Entry:
    """One cached weight: host plan + content hash + lazy device lowerings.

    ``device`` is keyed by the *compile-hook implementation* (the unbound
    function) that produced the lowering: backends sharing one hook (the
    built-in engine_jit / engine_pallas pair) share one memoised pytree,
    while a custom backend overriding ``compile`` with its own layout is
    never served another backend's arrays."""
    plan: ExecutionPlan
    fingerprint: str
    device: dict[Any, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class _Pending:
    """An in-flight plan build: the first thread to miss a key builds the
    plan OUTSIDE the cache lock; concurrent lookups of the same key wait
    on ``event`` instead of re-building (or blocking every other key).

    ``dead`` is the invalidation tombstone: an ``invalidate`` /
    ``invalidate_version`` / ``clear`` that lands while the build is in
    flight cannot remove an entry that is not published yet, so it marks
    the pending slot instead and the builder discards the finished plan
    at publish time — the callers that already coalesced on this build
    still receive the plan (they looked up before the invalidation), but
    the cache never retains it."""
    event: threading.Event
    entry: "_Entry | None" = None
    error: BaseException | None = None
    dead: bool = False


def weight_fingerprint(qw: np.ndarray) -> str:
    """Content hash of a quantized weight (shape + dtype + bytes)."""
    a = np.ascontiguousarray(np.asarray(qw))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _canonical(qw: np.ndarray) -> np.ndarray:
    """Canonical int8 view of a quantized weight for cache keying.

    The plan built from a weight depends on its *values*, not its array
    dtype — but the fingerprint hashes bytes, so the same weight passed
    as int8 (the qlinear callback view) and int64 (a precompile walk)
    would otherwise double-plan under two keys. int8 is the repo's
    quantized-weight universe (w_bits <= 8) and also makes the per-call
    content hash 8x cheaper than int64 bytes. Range-guarded: a silent
    wrap here would build a plan for the wrong values.
    """
    qw = np.asarray(qw)
    if not np.issubdtype(qw.dtype, np.integer):
        raise TypeError(f"quantized weights must be integer, got {qw.dtype}")
    if qw.dtype != np.int8:
        # wider dtypes need the wrap guard + a conversion copy; int8 input
        # (the serving hot path) passes through untouched — no value scan
        if qw.size and (qw.min() < -128 or qw.max() > 127):
            raise ValueError(
                "weight values outside int8 range — PlanCache covers "
                "int8-range quantized weights (w_bits <= 8)")
        qw = qw.astype(np.int8)
    return qw


def _coerce_cfg(cfg, t, groups) -> EngineConfig:
    """One EngineConfig from either the dataclass or the legacy ints."""
    if isinstance(cfg, EngineConfig):
        if t is not None or groups != 1:
            raise TypeError("pass either an EngineConfig (which carries t "
                            "and groups) or the legacy (w_bits, t, groups) "
                            "ints, not both")
        return cfg
    if t is None:
        raise TypeError("legacy int form needs t: (qw, w_bits, t, groups)")
    return EngineConfig(w_bits=int(cfg), t=int(t), groups=int(groups))


def _backend_tag(backend) -> str | None:
    """Normalise a counter tag: registry name, backend object, or None."""
    if backend is None:
        return None
    return backend if isinstance(backend, str) else backend.name


class PlanCache:
    """LRU cache of weight-only execution plans.

    Keyed by ``(weight fingerprint, w_bits, T, groups)``: the fingerprint
    covers the integer weight content, the remaining fields cover everything
    else :meth:`BatchedTransitiveEngine.plan` depends on. All operations are
    lock-protected — host callbacks may fire from XLA worker threads.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._plans: OrderedDict[PlanKey, _Entry] = OrderedDict()
        self._pending: dict[PlanKey, _Pending] = {}
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0
        # per-backend attribution of hits/misses (keyed by registry name);
        # untagged lookups only move the global counters
        self._backend_stats: dict[str, dict[str, int]] = {}

    def _count(self, backend: str | None, field: str) -> None:
        """Caller holds the lock. Bumps global + per-backend counters."""
        setattr(self, field, getattr(self, field) + 1)
        if backend is not None:
            per = self._backend_stats.setdefault(
                backend, {"hits": 0, "misses": 0})
            per[field] += 1

    # -- lookup / build ---------------------------------------------------
    def _entry(self, qw: np.ndarray, cfg: EngineConfig,
               version: Hashable | None,
               backend: str | None = None) -> _Entry:
        """Shared lookup/build path; counts one hit or one miss.

        The lock only guards the map + counters. Canonicalisation,
        fingerprinting and the plan build itself all run OUTSIDE it — a
        cold build (seconds for a large weight) must not stall concurrent
        hot-path lookups from XLA callback threads. Concurrent misses of
        the *same* key coalesce on a :class:`_Pending` slot: exactly one
        thread builds (counted as the miss), the rest wait on its event
        and count as hits — ``misses == distinct weights`` and
        ``hits + misses == lookups`` stay true under any interleaving.
        """
        qw = np.asarray(qw)
        if qw.ndim != 2:
            raise ValueError(f"qw must be 2-D (N, K), got {qw.shape}")
        sig = cfg.key()
        fp = None
        if version is not None:
            # fast key: the weight array is not even scanned on a hit
            key = ("v", version) + sig
        else:
            # canonical values (any dtype -> one key), then hash
            qw = _canonical(qw)
            fp = weight_fingerprint(qw)
            key = ("fp", fp) + sig
        while True:
            builder = False
            with self._lock:
                entry = self._plans.get(key)
                if entry is not None:
                    self._count(backend, "hits")
                    self._plans.move_to_end(key)
                    return entry
                pending = self._pending.get(key)
                if pending is None:
                    pending = _Pending(threading.Event())
                    self._pending[key] = pending
                    self._count(backend, "misses")
                    builder = True
            if builder:
                return self._build(pending, key, qw, cfg, fp, version)
            # someone else is building this key: wait off-lock, then
            # count the coalesced lookup as a hit
            pending.event.wait()
            if pending.entry is not None:
                with self._lock:
                    self._count(backend, "hits")
                return pending.entry
            # the builder failed — loop back and try building ourselves
            # (its exception already propagated to its own caller)

    def _build(self, pending: _Pending, key: PlanKey, qw: np.ndarray,
               cfg: EngineConfig, fp: str | None,
               version: Hashable | None) -> _Entry:
        """Build a plan outside the lock and publish it (double-checked:
        the pending slot guarantees no concurrent build of this key)."""
        try:
            if version is not None:
                qw = _canonical(qw)        # build path only
            plan = BatchedTransitiveEngine(bits=cfg.w_bits, t=cfg.t).plan(
                qw.astype(np.int64, copy=False), groups=cfg.groups)
            # trust boundary: nothing malformed is ever published to
            # readers (a failed verification propagates like a failed
            # build — waiters retry, nothing is cached)
            from repro.analysis.planlint import gate_plan
            gate_plan(plan, where="cache-publish")
            # content hash stored regardless of key scheme: invalidate()
            # finds version-keyed entries by weight content too
            entry = _Entry(plan=plan,
                           fingerprint=fp or weight_fingerprint(qw))
        except BaseException as e:
            with self._lock:
                self._pending.pop(key, None)
            pending.error = e
            pending.event.set()
            raise
        with self._lock:
            if pending.dead:
                # an invalidation raced the build: discard instead of
                # publishing (the dead entry must not be resurrected).
                # The builder and any coalesced waiters still get the
                # plan — they looked up before the invalidation landed.
                self.invalidations += 1
            else:
                self._plans[key] = entry
                while len(self._plans) > self.capacity:
                    self._plans.popitem(last=False)
                    self.evictions += 1
            self._pending.pop(key, None)
        pending.entry = entry
        pending.event.set()
        return entry

    def get_or_build(self, qw: np.ndarray, cfg, t: int | None = None,
                     groups: int = 1, *, version: Hashable | None = None,
                     backend=None) -> ExecutionPlan:
        """Return the cached plan for ``qw`` (N, K), building it on miss.

        ``cfg`` is an :class:`EngineConfig` (preferred) or the legacy
        ``w_bits`` int followed by ``t`` / ``groups``. ``qw`` is the full
        2-D integer weight with all quantization groups concatenated along
        K; grouped layers get one batched plan covering every group.
        ``backend=`` (a registry name or backend object) attributes the
        hit/miss to that backend in :meth:`stats`.

        With ``version=`` the caller's tag (layer id + step counter, any
        hashable) is the cache key and the weight bytes are hashed only
        when the plan is first built — the fast path for serving loops
        that would otherwise fingerprint identical bytes on every call. A
        given weight must be looked up under one scheme consistently;
        mixing builds it twice.

        Version keys trade away the content key's staleness immunity: a
        reused tag over *updated* weight bytes returns the old plan. Bump
        the tag on every weight update (that is what the step counter is
        for), or drop it via :meth:`invalidate_version` /
        :meth:`invalidate` with the OLD bytes, before looking up again.
        """
        cfg = _coerce_cfg(cfg, t, groups)
        return self._entry(qw, cfg, version, _backend_tag(backend)).plan

    def get_or_build_device(self, qw: np.ndarray, cfg,
                            t: int | None = None, groups: int = 1, *,
                            version: Hashable | None = None,
                            backend=None) -> DevicePlan:
        """Like :meth:`get_or_build`, but returns the device lowering.

        The lowering is compiled once per (entry, ``compile``-hook
        implementation) — through the requesting backend's hook (default
        ``engine_jit`` when the tag names no device lowering) — and
        memoised on the entry; repeated calls return the same pytree (so
        jit caches keyed on leaf shapes stay warm), and backends sharing
        one hook (engine_jit / engine_pallas) share one pytree."""
        cfg = _coerce_cfg(cfg, t, groups)
        tag = _backend_tag(backend)
        entry = self._entry(qw, cfg, version, tag)
        # a passed backend *instance* compiles through its own hook even if
        # it is not (or no longer) the registered one under that name
        if isinstance(backend, TransitiveBackend):
            bk = backend
        else:
            bk = get_backend(tag) if tag is not None else None
        if bk is None or not (bk.device_resident and bk.needs_plan):
            bk = get_backend("engine_jit")   # the default lowering
        memo_key = type(bk).compile          # the hook implementation
        if memo_key not in entry.device:
            # lower OUTSIDE the lock — index-array construction + device
            # transfer must not block concurrent hot-path lookups.
            # Double-checked: a racing compile keeps the first pytree.
            device = bk.compile(entry.plan)
            # second half of the publish gate: the lowering must agree
            # with the (already-verified) plan before any reader sees it
            from repro.analysis.planlint import gate_device
            gate_device(device, plan=entry.plan, backend=tag,
                        where="cache-lowering")
            with self._lock:
                entry.device.setdefault(memo_key, device)
        return entry.device[memo_key]

    def run(self, qw: np.ndarray, x: np.ndarray, cfg,
            t: int | None = None, groups: int = 1, *,
            version: Hashable | None = None, backend=None) -> np.ndarray:
        """Cached GEMM: plan on first sight of ``qw``, run-only after."""
        cfg = _coerce_cfg(cfg, t, groups)
        plan = self.get_or_build(qw, cfg, version=version, backend=backend)
        return BatchedTransitiveEngine(bits=plan.bits, t=plan.t).run(plan, x)

    # -- invalidation -----------------------------------------------------
    def invalidate(self, qw: np.ndarray) -> int:
        """Drop every cached plan built FROM this weight content (any
        bits/T/groups — version-keyed entries included, via the
        fingerprint stored at build time).

        Pass the bytes the stale plans were built from, i.e. the **old**
        weights: hashing the new bytes matches nothing. When an in-place
        update has destroyed the old bytes, version-keyed callers use
        :meth:`invalidate_version` (or simply bump the tag) instead.

        In-flight builds of the same content key are tombstoned: a
        build coalescing on a ``_Pending`` slot when the invalidation
        lands finishes but is discarded at publish time rather than
        resurrecting the dead entry (counted as an invalidation then).
        Returns the number of published entries removed now."""
        fp = weight_fingerprint(_canonical(qw))
        with self._lock:
            stale = [k for k, e in self._plans.items()
                     if e.fingerprint == fp]
            for k in stale:
                del self._plans[k]
            self.invalidations += len(stale)
            for k, p in self._pending.items():
                if k[0] == "fp" and k[1] == fp:
                    p.dead = True
            return len(stale)

    def invalidate_version(self, version: Hashable) -> int:
        """Drop every version-keyed entry with this tag (any bits/T/groups).

        The tag-side counterpart of :meth:`invalidate` for weight updates
        where the old bytes are gone (in-place param donation): without
        it, a reused tag would serve the old weights' plan silently.
        In-flight builds under this tag are tombstoned like
        :meth:`invalidate` tombstones content keys.
        Returns the number of published entries removed now."""
        with self._lock:
            stale = [k for k in self._plans
                     if k[0] == "v" and k[1] == version]
            for k in stale:
                del self._plans[k]
            self.invalidations += len(stale)
            for k, p in self._pending.items():
                if k[0] == "v" and k[1] == version:
                    p.dead = True
            return len(stale)

    def clear(self) -> None:
        """Drop all entries (counts them as invalidations); in-flight
        builds are tombstoned so they cannot repopulate the cache."""
        with self._lock:
            self.invalidations += len(self._plans)
            self._plans.clear()
            for p in self._pending.values():
                p.dead = True

    def reserve(self, n_plans: int) -> None:
        """Grow capacity to hold at least ``n_plans`` entries (never shrinks).

        Precompile calls this with the model's total plan count so a large
        model cannot LRU-thrash its own warmup."""
        with self._lock:
            self.capacity = max(self.capacity, int(n_plans))

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = 0
            self.evictions = self.invalidations = 0
            self._backend_stats = {}

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def stats(self) -> dict[str, Any]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "invalidations": self.invalidations,
                    "size": len(self._plans), "capacity": self.capacity,
                    "backends": {b: dict(s)
                                 for b, s in self._backend_stats.items()}}

    def __repr__(self) -> str:
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['capacity']} "
                f"hits={s['hits']} misses={s['misses']} "
                f"evictions={s['evictions']} "
                f"invalidations={s['invalidations']})")


# -- process-level default cache (the serving path's handle) ---------------

_default_cache = PlanCache()


def default_cache() -> PlanCache:
    """The process-level cache used by the qlinear engine callbacks."""
    return _default_cache


def set_default_cache(cache: PlanCache) -> PlanCache:
    """Swap the process-level cache (tests / per-session isolation);
    returns the previous one."""
    global _default_cache
    prev = _default_cache
    _default_cache = cache
    return prev


# -- offline precompile pass ------------------------------------------------

def _is_ptq_layer(tree: Any) -> bool:
    """The one definition of 'this dict is a PTQ linear layer'."""
    return isinstance(tree, dict) and "qw" in tree and "sg" in tree


def _layer_groups(sg: np.ndarray) -> int:
    """sg's trailing axis is the per-group scale count: 1 = per-channel."""
    return int(sg.shape[-1]) if sg.ndim else 1


def _iter_ptq_layers(tree: Any) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (qw, sg) leaf pairs from a params pytree of nested dicts."""
    if _is_ptq_layer(tree):
        yield np.asarray(tree["qw"]), np.asarray(tree["sg"])
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _iter_ptq_layers(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_ptq_layers(v)


def _plan_knobs(cfg) -> tuple[int, int]:
    """(w_bits, t) from a QuantConfig (transrow_t) or EngineConfig (t)."""
    t = getattr(cfg, "transrow_t", None)
    if t is None:
        t = cfg.t
    return int(cfg.w_bits), int(t)


def _cfg_backend(cfg, backend):
    """Resolve the backend a precompile/attach pass is on behalf of.

    Explicit ``backend=`` wins; else a ``QuantConfig``-shaped ``cfg``
    names its own backend; else None (counters stay unattributed)."""
    if backend is not None:
        return get_backend(backend)
    named = getattr(cfg, "backend_name", None)
    if callable(named):
        return get_backend(named())
    return None


def precompile(params: Any, cfg: Any, cache: PlanCache | None = None, *,
               backend=None) -> dict[str, int]:
    """Build every PTQ layer's ExecutionPlan once, ahead of serving.

    Walks ``params`` for ``{"qw", "sg"}`` layer dicts — including weights
    stacked along leading axes by the scan-over-super-blocks model init —
    and warms ``cache`` (default: the process cache) with one batched plan
    per distinct (weight, group) pair. ``cfg`` needs ``w_bits`` and
    ``transrow_t`` attributes (a ``QuantConfig`` works; an
    :class:`EngineConfig` too). ``backend=`` overrides which registry
    backend the cache counters attribute the builds to (default: the one
    ``cfg`` names, if any).

    Returns ``{"layers": stacked leaf count, "plans": plan-build calls,
    "built": cold builds (== new cache misses)}``.
    """
    cache = default_cache() if cache is None else cache
    b = _cfg_backend(cfg, backend)
    tag = b.name if b is not None else None
    w_bits, t = _plan_knobs(cfg)
    misses0 = cache.stats()["misses"]
    leaves = list(_iter_ptq_layers(params))
    # Size the cache to the model BEFORE building: otherwise a model with
    # more distinct weights than capacity evicts its own warmup and decode
    # silently re-plans every call.
    total = sum(int(np.prod(qw.shape[:-2], dtype=np.int64))
                for qw, _ in leaves)
    cache.reserve(total)
    layers = plans = 0
    for qw, sg in leaves:
        layers += 1
        ecfg = EngineConfig(w_bits=w_bits, t=t, groups=_layer_groups(sg))
        lead = qw.shape[:-2]
        for idx in np.ndindex(*lead):
            cache.get_or_build(qw[idx], ecfg, backend=tag)
            plans += 1
    return {"layers": layers, "plans": plans,
            "built": cache.stats()["misses"] - misses0}


def attach_device_plans(params: Any, cfg: Any,
                        cache: PlanCache | None = None, *,
                        mesh=None, specs=None, backend=None) -> Any:
    """Return a copy of ``params`` with a compiled ``"dplan"`` per PTQ layer.

    For every ``{"qw", "sg"}`` layer dict the quantized weight's
    :class:`DevicePlan` is compiled — through the serving backend's
    ``compile`` hook — and embedded next to the weight; leaves with
    vmap/scan leading axes get one plan per slice, padded to shared bounds
    and **stacked along the same leading axes**, so ``lax.scan`` over
    stacked super-blocks slices the plan exactly like it slices the
    weight. The device backends in ``quant/qlinear.py`` then execute
    pure-JAX from the embedded plan even where ``qw`` is a tracer — the
    host callback is gone from the hot path entirely.

    ``backend=`` selects the registry backend whose ``compile`` hook lowers
    the plans (default: the one ``cfg`` names, else ``engine_jit`` — every
    built-in device backend shares the same lowering). With ``mesh=`` each
    embedded plan's leaves are placed under ``specs``
    (:func:`~repro.core.backend.shard_device_plan`) — e.g.
    ``specs=P("data")`` shards the stacked leading axis across the mesh for
    multi-device serving. When ``specs`` is omitted the placement is
    capability-keyed: the backend's own ``plan_specs(mesh)`` hook decides
    (built-ins replicate — the data-parallel serve-cell default).

    Host ExecutionPlans are built through ``cache`` (default: process
    cache), so a preceding :func:`precompile` warmup is reused, not
    repeated. ``cfg`` needs ``w_bits`` and ``transrow_t`` (a
    ``QuantConfig`` works; an :class:`EngineConfig` too).

    An embedded plan is a snapshot: it is only as fresh as this call. On
    any weight update, ``invalidate`` the cache **and re-attach** — the
    qlinear consistency check catches config/shape drift but cannot see
    weight content (the weight is a tracer on the hot path).
    """
    import jax

    cache = default_cache() if cache is None else cache
    b = _cfg_backend(cfg, backend)
    if b is None:
        b = get_backend("engine_jit")
    if not (b.needs_plan and b.device_resident):
        raise ValueError(
            f"backend '{b.name}' does not execute from device plans; "
            f"attach_device_plans serves device-resident planned backends "
            f"(e.g. engine_jit, engine_pallas)")
    if mesh is not None and specs is None:
        specs = b.plan_specs(mesh)
    w_bits, t = _plan_knobs(cfg)
    # size the cache to the model before building, like precompile: the
    # attach walk must not LRU-evict its own (or a prior warmup's) plans
    cache.reserve(sum(
        int(np.prod(qw.shape[:-2], dtype=np.int64))
        for qw, _ in _iter_ptq_layers(params)))

    def walk(tree: Any) -> Any:
        if isinstance(tree, dict):
            if _is_ptq_layer(tree):
                qw = np.asarray(tree["qw"])
                sg = np.asarray(tree["sg"])
                ecfg = EngineConfig(w_bits=w_bits, t=t,
                                    groups=_layer_groups(sg))
                lead = qw.shape[:-2]
                if lead:
                    # stacked leaves share direct-dispatch bounds, so they
                    # are lowered together rather than via the per-entry
                    # device memo
                    plans = [cache.get_or_build(qw[idx], ecfg,
                                                backend=b.name)
                             for idx in np.ndindex(*lead)]
                    compiled = b.compile(plans)
                    if not isinstance(compiled, DevicePlan):
                        raise NotImplementedError(
                            f"backend '{b.name}' compiles a custom plan "
                            f"layout; the stacked/sharded attach walk "
                            f"handles the standard DevicePlan only — "
                            f"stack and place custom layouts inside the "
                            f"backend's compile hook")
                    dplan = jax.tree.map(
                        lambda a: a.reshape(lead + a.shape[1:]), compiled)
                else:
                    dplan = cache.get_or_build_device(qw, ecfg,
                                                      backend=b.name)
                if mesh is not None:
                    if not isinstance(dplan, DevicePlan):
                        raise NotImplementedError(
                            f"backend '{b.name}' compiles a custom plan "
                            f"layout; mesh placement is only automatic "
                            f"for the standard DevicePlan")
                    dplan = shard_device_plan(dplan, mesh, specs)
                return {**tree, "dplan": dplan}
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v) for v in tree]
        if isinstance(tree, tuple):
            return tuple(walk(v) for v in tree)
        return tree

    return walk(params)
