"""Process-level ExecutionPlan cache for the serving path.

The paper's offline/online split (TransRow packing + Scoreboard build are
weight-only; only forest execution depends on activations) only pays off if
the offline half runs **once per weight**, not once per forward call. This
module is that amortisation, as a first-class subsystem:

  * :class:`PlanCache` — an LRU-bounded map from
    ``(weight fingerprint, w_bits, T, groups)`` to a ready
    :class:`~repro.core.engine.ExecutionPlan`, with hit / miss / eviction /
    invalidation counters so serving can *prove* each plan was built exactly
    once (misses == distinct quantized weights, hits == remaining calls).
  * a process-level default cache that the jit-side host callbacks in
    ``quant/qlinear.py`` consult on every engine forward — the hot path only
    ever executes ``run(plan, x)``.
  * :func:`precompile` — an offline pass that walks a model's params pytree
    (including vmap-stacked leading axes from scanned super-blocks) and
    builds every PTQ layer's plan up front, so the first decoded token pays
    zero plan-build cost.

Weights are fingerprinted by content (blake2b over shape/dtype/bytes), so a
weight *update* naturally misses — and :meth:`PlanCache.invalidate` drops
the stale entry explicitly so updated-weight serving does not leak plans
until LRU pressure finds them. Content keys make correctness unconditional
(no way to serve a stale plan) at the cost of hashing the int8 weight bytes
per lookup. Callers that manage their own weight identity (a layer id plus
a step counter, say) can pass ``version=`` instead: the tag becomes the
lookup key and the bytes are only hashed once, at build time, so
:meth:`invalidate` stays content-based and can still find version-keyed
entries when the weight updates.

Two plan representations live behind the same keys: the host-numpy
:class:`~repro.core.engine.ExecutionPlan` (built once per weight) and the
device-resident :class:`~repro.core.engine.DevicePlan` it lowers to
(:meth:`get_or_build_device`, compiled lazily from the cached host plan).
:func:`attach_device_plans` embeds compiled plans *into a params pytree* —
stacked along any vmap/scan leading axes — which is how the pure-JAX
``path="engine_jit"`` serving hot path (quant/qlinear.py) sees plans for
weights that are tracers inside the model's block scan.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
from collections import OrderedDict
from typing import Any, Hashable, Iterator

import numpy as np

from repro.core.engine import (BatchedTransitiveEngine, DevicePlan,
                               ExecutionPlan, compile_plan, compile_plans)

__all__ = ["PlanCache", "weight_fingerprint", "default_cache",
           "set_default_cache", "precompile", "attach_device_plans"]

# ("fp", content-hash, bits, t, groups) or ("v", version-tag, bits, t, groups)
PlanKey = tuple


@dataclasses.dataclass
class _Entry:
    """One cached weight: host plan + content hash + lazy device lowering."""
    plan: ExecutionPlan
    fingerprint: str
    device: DevicePlan | None = None


def weight_fingerprint(qw: np.ndarray) -> str:
    """Content hash of a quantized weight (shape + dtype + bytes)."""
    a = np.ascontiguousarray(np.asarray(qw))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return h.hexdigest()


def _canonical(qw: np.ndarray) -> np.ndarray:
    """Canonical int8 view of a quantized weight for cache keying.

    The plan built from a weight depends on its *values*, not its array
    dtype — but the fingerprint hashes bytes, so the same weight passed
    as int8 (the qlinear callback view) and int64 (a precompile walk)
    would otherwise double-plan under two keys. int8 is the repo's
    quantized-weight universe (w_bits <= 8) and also makes the per-call
    content hash 8x cheaper than int64 bytes. Range-guarded: a silent
    wrap here would build a plan for the wrong values.
    """
    qw = np.asarray(qw)
    if not np.issubdtype(qw.dtype, np.integer):
        raise TypeError(f"quantized weights must be integer, got {qw.dtype}")
    if qw.dtype != np.int8:
        # wider dtypes need the wrap guard + a conversion copy; int8 input
        # (the serving hot path) passes through untouched — no value scan
        if qw.size and (qw.min() < -128 or qw.max() > 127):
            raise ValueError(
                "weight values outside int8 range — PlanCache covers "
                "int8-range quantized weights (w_bits <= 8)")
        qw = qw.astype(np.int8)
    return qw


class PlanCache:
    """LRU cache of weight-only execution plans.

    Keyed by ``(weight fingerprint, w_bits, T, groups)``: the fingerprint
    covers the integer weight content, the remaining fields cover everything
    else :meth:`BatchedTransitiveEngine.plan` depends on. All operations are
    lock-protected — host callbacks may fire from XLA worker threads.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._plans: OrderedDict[PlanKey, _Entry] = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    # -- lookup / build ---------------------------------------------------
    def _entry(self, qw: np.ndarray, w_bits: int, t: int, groups: int,
               version: Hashable | None) -> _Entry:
        """Shared lookup/build path; counts one hit or one miss."""
        qw = np.asarray(qw)
        if qw.ndim != 2:
            raise ValueError(f"qw must be 2-D (N, K), got {qw.shape}")
        sig = (int(w_bits), int(t), int(groups))
        with self._lock:
            fp = None
            if version is not None:
                # fast key: the weight array is not even scanned on a hit
                key = ("v", version) + sig
            else:
                # canonical values (any dtype -> one key), then hash
                qw = _canonical(qw)
                fp = weight_fingerprint(qw)
                key = ("fp", fp) + sig
            entry = self._plans.get(key)
            if entry is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return entry
            if version is not None:
                qw = _canonical(qw)        # build path only
            self.misses += 1
            plan = BatchedTransitiveEngine(bits=w_bits, t=t).plan(
                qw.astype(np.int64, copy=False), groups=groups)
            # content hash stored regardless of key scheme: invalidate()
            # finds version-keyed entries by weight content too
            entry = _Entry(plan=plan,
                           fingerprint=fp or weight_fingerprint(qw))
            self._plans[key] = entry
            while len(self._plans) > self.capacity:
                self._plans.popitem(last=False)
                self.evictions += 1
            return entry

    def get_or_build(self, qw: np.ndarray, w_bits: int, t: int,
                     groups: int = 1, *,
                     version: Hashable | None = None) -> ExecutionPlan:
        """Return the cached plan for ``qw`` (N, K), building it on miss.

        ``qw`` is the full 2-D integer weight with all quantization groups
        concatenated along K; grouped layers pass ``groups=G`` and get one
        batched plan covering every group. With ``version=`` the caller's
        tag (layer id + step counter, any hashable) is the cache key and
        the weight bytes are hashed only when the plan is first built —
        the fast path for serving loops that would otherwise fingerprint
        identical bytes on every call. A given weight must be looked up
        under one scheme consistently; mixing builds it twice.

        Version keys trade away the content key's staleness immunity: a
        reused tag over *updated* weight bytes returns the old plan. Bump
        the tag on every weight update (that is what the step counter is
        for), or drop it via :meth:`invalidate_version` /
        :meth:`invalidate` with the OLD bytes, before looking up again.
        """
        return self._entry(qw, w_bits, t, groups, version).plan

    def get_or_build_device(self, qw: np.ndarray, w_bits: int, t: int,
                            groups: int = 1, *,
                            version: Hashable | None = None) -> DevicePlan:
        """Like :meth:`get_or_build`, but returns the device lowering.

        The :class:`DevicePlan` is compiled once from the cached host plan
        and memoised on the entry; repeated calls return the same pytree
        (so jit caches keyed on leaf shapes stay warm)."""
        entry = self._entry(qw, w_bits, t, groups, version)
        if entry.device is None:
            # lower OUTSIDE the lock — index-array construction + device
            # transfer must not block concurrent hot-path lookups.
            # Double-checked: a racing compile keeps the first pytree.
            device = compile_plan(entry.plan)
            with self._lock:
                if entry.device is None:
                    entry.device = device
        return entry.device

    def run(self, qw: np.ndarray, x: np.ndarray, w_bits: int, t: int,
            groups: int = 1, *,
            version: Hashable | None = None) -> np.ndarray:
        """Cached GEMM: plan on first sight of ``qw``, run-only after."""
        plan = self.get_or_build(qw, w_bits, t, groups, version=version)
        return BatchedTransitiveEngine(bits=plan.bits, t=plan.t).run(plan, x)

    # -- invalidation -----------------------------------------------------
    def invalidate(self, qw: np.ndarray) -> int:
        """Drop every cached plan built FROM this weight content (any
        bits/T/groups — version-keyed entries included, via the
        fingerprint stored at build time).

        Pass the bytes the stale plans were built from, i.e. the **old**
        weights: hashing the new bytes matches nothing. When an in-place
        update has destroyed the old bytes, version-keyed callers use
        :meth:`invalidate_version` (or simply bump the tag) instead.
        Returns the number of entries removed."""
        fp = weight_fingerprint(_canonical(qw))
        with self._lock:
            stale = [k for k, e in self._plans.items()
                     if e.fingerprint == fp]
            for k in stale:
                del self._plans[k]
            self.invalidations += len(stale)
            return len(stale)

    def invalidate_version(self, version: Hashable) -> int:
        """Drop every version-keyed entry with this tag (any bits/T/groups).

        The tag-side counterpart of :meth:`invalidate` for weight updates
        where the old bytes are gone (in-place param donation): without
        it, a reused tag would serve the old weights' plan silently.
        Returns the number of entries removed."""
        with self._lock:
            stale = [k for k in self._plans
                     if k[0] == "v" and k[1] == version]
            for k in stale:
                del self._plans[k]
            self.invalidations += len(stale)
            return len(stale)

    def clear(self) -> None:
        """Drop all entries (counts them as invalidations)."""
        with self._lock:
            self.invalidations += len(self._plans)
            self._plans.clear()

    def reserve(self, n_plans: int) -> None:
        """Grow capacity to hold at least ``n_plans`` entries (never shrinks).

        Precompile calls this with the model's total plan count so a large
        model cannot LRU-thrash its own warmup."""
        with self._lock:
            self.capacity = max(self.capacity, int(n_plans))

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = 0
            self.evictions = self.invalidations = 0

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "invalidations": self.invalidations,
                    "size": len(self._plans), "capacity": self.capacity}

    def __repr__(self) -> str:
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['capacity']} "
                f"hits={s['hits']} misses={s['misses']} "
                f"evictions={s['evictions']} "
                f"invalidations={s['invalidations']})")


# -- process-level default cache (the serving path's handle) ---------------

_default_cache = PlanCache()


def default_cache() -> PlanCache:
    """The process-level cache used by the qlinear engine callbacks."""
    return _default_cache


def set_default_cache(cache: PlanCache) -> PlanCache:
    """Swap the process-level cache (tests / per-session isolation);
    returns the previous one."""
    global _default_cache
    prev = _default_cache
    _default_cache = cache
    return prev


# -- offline precompile pass ------------------------------------------------

def _is_ptq_layer(tree: Any) -> bool:
    """The one definition of 'this dict is a PTQ linear layer'."""
    return isinstance(tree, dict) and "qw" in tree and "sg" in tree


def _layer_groups(sg: np.ndarray) -> int:
    """sg's trailing axis is the per-group scale count: 1 = per-channel."""
    return int(sg.shape[-1]) if sg.ndim else 1


def _iter_ptq_layers(tree: Any) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (qw, sg) leaf pairs from a params pytree of nested dicts."""
    if _is_ptq_layer(tree):
        yield np.asarray(tree["qw"]), np.asarray(tree["sg"])
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _iter_ptq_layers(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_ptq_layers(v)


def precompile(params: Any, cfg: Any,
               cache: PlanCache | None = None) -> dict[str, int]:
    """Build every PTQ layer's ExecutionPlan once, ahead of serving.

    Walks ``params`` for ``{"qw", "sg"}`` layer dicts — including weights
    stacked along leading axes by the scan-over-super-blocks model init —
    and warms ``cache`` (default: the process cache) with one batched plan
    per distinct (weight, group) pair. ``cfg`` needs ``w_bits`` and
    ``transrow_t`` attributes (a ``QuantConfig`` works).

    Returns ``{"layers": stacked leaf count, "plans": plan-build calls,
    "built": cold builds (== new cache misses)}``.
    """
    cache = default_cache() if cache is None else cache
    misses0 = cache.stats()["misses"]
    leaves = list(_iter_ptq_layers(params))
    # Size the cache to the model BEFORE building: otherwise a model with
    # more distinct weights than capacity evicts its own warmup and decode
    # silently re-plans every call.
    total = sum(int(np.prod(qw.shape[:-2], dtype=np.int64))
                for qw, _ in leaves)
    cache.reserve(total)
    layers = plans = 0
    for qw, sg in leaves:
        layers += 1
        groups = _layer_groups(sg)
        lead = qw.shape[:-2]
        for idx in np.ndindex(*lead):
            cache.get_or_build(qw[idx], cfg.w_bits, cfg.transrow_t,
                               groups=groups)
            plans += 1
    return {"layers": layers, "plans": plans,
            "built": cache.stats()["misses"] - misses0}


def attach_device_plans(params: Any, cfg: Any,
                        cache: PlanCache | None = None) -> Any:
    """Return a copy of ``params`` with a compiled ``"dplan"`` per PTQ layer.

    For every ``{"qw", "sg"}`` layer dict the quantized weight's
    :class:`DevicePlan` is compiled and embedded next to the weight; leaves
    with vmap/scan leading axes get one plan per slice, padded to shared
    bounds and **stacked along the same leading axes**, so ``lax.scan``
    over stacked super-blocks slices the plan exactly like it slices the
    weight. ``quant/qlinear.py`` ``path="engine_jit"``/``"engine_pallas"``
    then execute pure-JAX from the embedded plan even where ``qw`` is a
    tracer — the host callback is gone from the hot path entirely.

    Host ExecutionPlans are built through ``cache`` (default: process
    cache), so a preceding :func:`precompile` warmup is reused, not
    repeated. ``cfg`` needs ``w_bits`` and ``transrow_t`` (a
    ``QuantConfig`` works).

    An embedded plan is a snapshot: it is only as fresh as this call. On
    any weight update, ``invalidate`` the cache **and re-attach** — the
    qlinear consistency check catches config/shape drift but cannot see
    weight content (the weight is a tracer on the hot path).
    """
    import jax

    cache = default_cache() if cache is None else cache
    # size the cache to the model before building, like precompile: the
    # attach walk must not LRU-evict its own (or a prior warmup's) plans
    cache.reserve(sum(
        int(np.prod(qw.shape[:-2], dtype=np.int64))
        for qw, _ in _iter_ptq_layers(params)))

    def walk(tree: Any) -> Any:
        if isinstance(tree, dict):
            if _is_ptq_layer(tree):
                qw = np.asarray(tree["qw"])
                sg = np.asarray(tree["sg"])
                groups = _layer_groups(sg)
                lead = qw.shape[:-2]
                if lead:
                    # stacked leaves share direct-dispatch bounds, so they
                    # are lowered together rather than via the per-entry
                    # device memo
                    plans = [cache.get_or_build(qw[idx], cfg.w_bits,
                                                cfg.transrow_t, groups)
                             for idx in np.ndindex(*lead)]
                    dplan = jax.tree.map(
                        lambda a: a.reshape(lead + a.shape[1:]),
                        compile_plans(plans))
                else:
                    dplan = cache.get_or_build_device(
                        qw, cfg.w_bits, cfg.transrow_t, groups)
                return {**tree, "dplan": dplan}
            return {k: walk(v) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v) for v in tree]
        if isinstance(tree, tuple):
            return tuple(walk(v) for v in tree)
        return tree

    return walk(params)
