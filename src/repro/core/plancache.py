"""Process-level ExecutionPlan cache for the serving path.

The paper's offline/online split (TransRow packing + Scoreboard build are
weight-only; only forest execution depends on activations) only pays off if
the offline half runs **once per weight**, not once per forward call. This
module is that amortisation, as a first-class subsystem:

  * :class:`PlanCache` — an LRU-bounded map from
    ``(weight fingerprint, w_bits, T, groups)`` to a ready
    :class:`~repro.core.engine.ExecutionPlan`, with hit / miss / eviction /
    invalidation counters so serving can *prove* each plan was built exactly
    once (misses == distinct quantized weights, hits == remaining calls).
  * a process-level default cache that the jit-side host callbacks in
    ``quant/qlinear.py`` consult on every engine forward — the hot path only
    ever executes ``run(plan, x)``.
  * :func:`precompile` — an offline pass that walks a model's params pytree
    (including vmap-stacked leading axes from scanned super-blocks) and
    builds every PTQ layer's plan up front, so the first decoded token pays
    zero plan-build cost.

Weights are fingerprinted by content (blake2b over shape/dtype/bytes), so a
weight *update* naturally misses — and :meth:`PlanCache.invalidate` drops
the stale entry explicitly so updated-weight serving does not leak plans
until LRU pressure finds them. Content keys make correctness unconditional
(no way to serve a stale plan) at the cost of hashing the int8 weight bytes
per lookup; that is noise next to this host-numpy engine's ``run``, but a
hardware lowering should switch the hot path to per-layer version tags and
keep content hashing for :meth:`invalidate` (see ROADMAP).

Plain numpy + stdlib — this is host-side state next to the host-side
engine; nothing here traces under jit.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Any, Iterator

import numpy as np

from repro.core.engine import BatchedTransitiveEngine, ExecutionPlan

__all__ = ["PlanCache", "weight_fingerprint", "default_cache",
           "set_default_cache", "precompile"]

PlanKey = tuple[str, int, int, int]


def weight_fingerprint(qw: np.ndarray) -> str:
    """Content hash of a quantized weight (shape + dtype + bytes)."""
    a = np.ascontiguousarray(np.asarray(qw))
    h = hashlib.blake2b(digest_size=16)
    h.update(repr((a.shape, a.dtype.str)).encode())
    h.update(a.tobytes())
    return h.hexdigest()


class PlanCache:
    """LRU cache of weight-only execution plans.

    Keyed by ``(weight fingerprint, w_bits, T, groups)``: the fingerprint
    covers the integer weight content, the remaining fields cover everything
    else :meth:`BatchedTransitiveEngine.plan` depends on. All operations are
    lock-protected — host callbacks may fire from XLA worker threads.
    """

    def __init__(self, capacity: int = 256):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._plans: OrderedDict[PlanKey, ExecutionPlan] = OrderedDict()
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    # -- lookup / build ---------------------------------------------------
    def get_or_build(self, qw: np.ndarray, w_bits: int, t: int,
                     groups: int = 1) -> ExecutionPlan:
        """Return the cached plan for ``qw`` (N, K), building it on miss.

        ``qw`` is the full 2-D integer weight with all quantization groups
        concatenated along K; grouped layers pass ``groups=G`` and get one
        batched plan covering every group.
        """
        qw = np.asarray(qw)
        if qw.ndim != 2:
            raise ValueError(f"qw must be 2-D (N, K), got {qw.shape}")
        key = (weight_fingerprint(qw), int(w_bits), int(t), int(groups))
        with self._lock:
            plan = self._plans.get(key)
            if plan is not None:
                self.hits += 1
                self._plans.move_to_end(key)
                return plan
            self.misses += 1
            plan = BatchedTransitiveEngine(bits=w_bits, t=t).plan(
                qw.astype(np.int64, copy=False), groups=groups)
            self._plans[key] = plan
            while len(self._plans) > self.capacity:
                self._plans.popitem(last=False)
                self.evictions += 1
            return plan

    def run(self, qw: np.ndarray, x: np.ndarray, w_bits: int, t: int,
            groups: int = 1) -> np.ndarray:
        """Cached GEMM: plan on first sight of ``qw``, run-only after."""
        plan = self.get_or_build(qw, w_bits, t, groups)
        return BatchedTransitiveEngine(bits=plan.bits, t=plan.t).run(plan, x)

    # -- invalidation -----------------------------------------------------
    def invalidate(self, qw: np.ndarray) -> int:
        """Drop every cached plan for this weight content (any bits/T/groups).

        Call on weight update; returns the number of entries removed."""
        fp = weight_fingerprint(qw)
        with self._lock:
            stale = [k for k in self._plans if k[0] == fp]
            for k in stale:
                del self._plans[k]
            self.invalidations += len(stale)
            return len(stale)

    def clear(self) -> None:
        """Drop all entries (counts them as invalidations)."""
        with self._lock:
            self.invalidations += len(self._plans)
            self._plans.clear()

    def reserve(self, n_plans: int) -> None:
        """Grow capacity to hold at least ``n_plans`` entries (never shrinks).

        Precompile calls this with the model's total plan count so a large
        model cannot LRU-thrash its own warmup."""
        with self._lock:
            self.capacity = max(self.capacity, int(n_plans))

    def reset_stats(self) -> None:
        with self._lock:
            self.hits = self.misses = 0
            self.evictions = self.invalidations = 0

    # -- introspection ----------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._plans)

    def stats(self) -> dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "invalidations": self.invalidations,
                    "size": len(self._plans), "capacity": self.capacity}

    def __repr__(self) -> str:
        s = self.stats()
        return (f"PlanCache(size={s['size']}/{s['capacity']} "
                f"hits={s['hits']} misses={s['misses']} "
                f"evictions={s['evictions']} "
                f"invalidations={s['invalidations']})")


# -- process-level default cache (the serving path's handle) ---------------

_default_cache = PlanCache()


def default_cache() -> PlanCache:
    """The process-level cache used by the qlinear engine callbacks."""
    return _default_cache


def set_default_cache(cache: PlanCache) -> PlanCache:
    """Swap the process-level cache (tests / per-session isolation);
    returns the previous one."""
    global _default_cache
    prev = _default_cache
    _default_cache = cache
    return prev


# -- offline precompile pass ------------------------------------------------

def _iter_ptq_layers(tree: Any) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (qw, sg) leaf pairs from a params pytree of nested dicts."""
    if isinstance(tree, dict):
        if "qw" in tree and "sg" in tree:
            yield np.asarray(tree["qw"]), np.asarray(tree["sg"])
            return
        for v in tree.values():
            yield from _iter_ptq_layers(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_ptq_layers(v)


def precompile(params: Any, cfg: Any,
               cache: PlanCache | None = None) -> dict[str, int]:
    """Build every PTQ layer's ExecutionPlan once, ahead of serving.

    Walks ``params`` for ``{"qw", "sg"}`` layer dicts — including weights
    stacked along leading axes by the scan-over-super-blocks model init —
    and warms ``cache`` (default: the process cache) with one batched plan
    per distinct (weight, group) pair. ``cfg`` needs ``w_bits`` and
    ``transrow_t`` attributes (a ``QuantConfig`` works).

    Returns ``{"layers": stacked leaf count, "plans": plan-build calls,
    "built": cold builds (== new cache misses)}``.
    """
    cache = default_cache() if cache is None else cache
    misses0 = cache.stats()["misses"]
    leaves = list(_iter_ptq_layers(params))
    # Size the cache to the model BEFORE building: otherwise a model with
    # more distinct weights than capacity evicts its own warmup and decode
    # silently re-plans every call.
    total = sum(int(np.prod(qw.shape[:-2], dtype=np.int64))
                for qw, _ in leaves)
    cache.reserve(total)
    layers = plans = 0
    for qw, sg in leaves:
        layers += 1
        # sg's trailing axis is the per-group scale count: 1 = per-channel.
        groups = int(sg.shape[-1]) if sg.ndim else 1
        lead = qw.shape[:-2]
        for idx in np.ndindex(*lead):
            cache.get_or_build(qw[idx], cfg.w_bits, cfg.transrow_t,
                               groups=groups)
            plans += 1
    return {"layers": layers, "plans": plans,
            "built": cache.stats()["misses"] - misses0}
