"""Hasse graph of the subset partial order over T-bit patterns (Sec. 2.3).

Nodes are integers in [0, 2^T). ``a <= b`` iff ``a & b == a`` (bitwise
subset). The Hasse graph keeps only covering edges: ``a -> b`` iff
``b = a | (1 << i)`` for a bit ``i`` not in ``a`` (distance 1 = one bit flip).

* **prefix** of b: any a with a <= b (a provides the reused partial sum).
* **suffix** of a: any b with a <= b.
* **level** of a node = popcount (its Hamming weight).
* **distance**(a, b) = level(b) - level(a) for a <= b.

All tables are precomputed once per T and cached — they are tiny
(2^T x T ints) and shared by the scoreboard, the cost model and the tests.
"""
from __future__ import annotations

import functools
import numpy as np

__all__ = [
    "popcount",
    "levels",
    "hamming_order",
    "covering_prefixes",
    "covering_suffixes",
    "is_prefix",
    "distance",
    "lsb_prefix",
]


def popcount(x: np.ndarray) -> np.ndarray:
    """Vectorised popcount for uint arrays."""
    x = np.asarray(x, dtype=np.uint64)
    c = np.zeros(x.shape, dtype=np.int64)
    while True:
        c += (x & 1).astype(np.int64)
        x = x >> np.uint64(1)
        if not x.any():
            break
    return c


@functools.lru_cache(maxsize=None)
def levels(t: int) -> np.ndarray:
    """Level (popcount) of every node in a T-bit Hasse graph. (2^T,) int64."""
    return popcount(np.arange(1 << t, dtype=np.uint64))


@functools.lru_cache(maxsize=None)
def hamming_order(t: int) -> np.ndarray:
    """All 2^T nodes sorted by level (stable within a level; Sec. 3.1).

    The paper's Alg. 1 line 3 traverses nodes level-by-level; ties carry no
    ordering requirement. Stable argsort keeps integer order within levels,
    matching the worked example in Fig. 5.
    """
    return np.argsort(levels(t), kind="stable").astype(np.int64)


@functools.lru_cache(maxsize=None)
def covering_prefixes(t: int) -> np.ndarray:
    """(2^T, T) int64: node with bit i cleared, or -1 if bit i not set."""
    n = 1 << t
    nodes = np.arange(n, dtype=np.int64)[:, None]
    bits = 1 << np.arange(t, dtype=np.int64)[None, :]
    has = (nodes & bits) != 0
    return np.where(has, nodes & ~bits, -1)


@functools.lru_cache(maxsize=None)
def covering_suffixes(t: int) -> np.ndarray:
    """(2^T, T) int64: node with bit i set, or -1 if bit i already set."""
    n = 1 << t
    nodes = np.arange(n, dtype=np.int64)[:, None]
    bits = 1 << np.arange(t, dtype=np.int64)[None, :]
    free = (nodes & bits) == 0
    return np.where(free, nodes | bits, -1)


def is_prefix(a: int | np.ndarray, b: int | np.ndarray) -> np.ndarray:
    """Whether ``a`` is a (non-strict) prefix of ``b`` in the partial order."""
    a = np.asarray(a, dtype=np.uint64)
    b = np.asarray(b, dtype=np.uint64)
    return (a & b) == a


def distance(a: int | np.ndarray, b: int | np.ndarray) -> np.ndarray:
    """Level difference for a <= b (undefined otherwise; caller checks)."""
    return popcount(b) - popcount(a)


def lsb_prefix(x: np.ndarray) -> np.ndarray:
    """The canonical doubling prefix: x with its lowest set bit cleared.

    This is the distance-1 prefix used by the dense-LUT TPU kernel
    (DESIGN.md §2): LUT[x] = LUT[x & (x-1)] + input_row[lsb(x)].
    """
    x = np.asarray(x, dtype=np.int64)
    return x & (x - 1)
