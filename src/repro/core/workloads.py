"""LLaMA / ResNet workload shape catalogues for the paper's evaluation.

Fig. 10 runs the FC layers of LLaMA 1/2/3; Fig. 12 the attention GEMMs at
sequence length 2048 (first transformer block, Sec. 5.1 — all blocks are
identical). Shapes follow the public model cards.
"""
from __future__ import annotations

from repro.core.costmodel import Gemm

__all__ = ["llama_fc_gemms", "llama_attention_gemms", "resnet18_gemms",
           "LLAMA_DIMS"]

# model: (d_model, d_ff, n_heads, n_kv_heads)
LLAMA_DIMS = {
    "llama1-7b": (4096, 11008, 32, 32),
    "llama1-13b": (5120, 13824, 40, 40),
    "llama1-30b": (6656, 17920, 52, 52),
    "llama1-65b": (8192, 22016, 64, 64),
    "llama2-7b": (4096, 11008, 32, 32),
    "llama2-13b": (5120, 13824, 40, 40),
    "llama3-8b": (4096, 14336, 32, 8),
}


def llama_fc_gemms(model: str, seq: int = 2048, w_bits: int = 8,
                   a_bits: int = 8) -> list[Gemm]:
    """FC (projection + FFN) GEMMs of one transformer block."""
    d, ff, h, kv = LLAMA_DIMS[model]
    hd = d // h
    return [
        Gemm(d, d, seq, w_bits, a_bits, "wq"),
        Gemm(kv * hd, d, seq, w_bits, a_bits, "wk"),
        Gemm(kv * hd, d, seq, w_bits, a_bits, "wv"),
        Gemm(d, d, seq, w_bits, a_bits, "wo"),
        Gemm(ff, d, seq, w_bits, a_bits, "w_gate"),
        Gemm(ff, d, seq, w_bits, a_bits, "w_up"),
        Gemm(d, ff, seq, w_bits, a_bits, "w_down"),
    ]


def llama_attention_gemms(model: str, seq: int = 2048, bits: int = 8) -> list[Gemm]:
    """Attention-score GEMMs (Q@K^T and P@V per head); K/V act as weights."""
    d, _, h, kv = LLAMA_DIMS[model]
    hd = d // h
    out = []
    for _ in range(h):
        out.append(Gemm(seq, hd, seq, bits, bits, "qk"))
        out.append(Gemm(seq, seq, hd, bits, bits, "pv"))
    return out


def resnet18_gemms(w_bits: int = 4, a_bits: int = 8) -> list[Gemm]:
    """ResNet-18 conv layers as im2col GEMMs (Sec. 5.10), ImageNet 224x224.

    First conv and final FC use 8-bit (Sec. 5.10); the rest w_bits.
    GEMM for conv: n=c_out, k=c_in*k_h*k_w, m=h_out*w_out.
    """
    # (c_in, c_out, kernel, h_out*w_out, repeats)
    layers = [
        (3, 64, 7, 112 * 112, 1),
        (64, 64, 3, 56 * 56, 4),
        (64, 128, 3, 28 * 28, 1), (128, 128, 3, 28 * 28, 3),
        (128, 256, 3, 14 * 14, 1), (256, 256, 3, 14 * 14, 3),
        (256, 512, 3, 7 * 7, 1), (512, 512, 3, 7 * 7, 3),
    ]
    gemms = []
    for i, (cin, cout, ks, hw, rep) in enumerate(layers):
        wb = 8 if i == 0 else w_bits
        for r in range(rep):
            gemms.append(Gemm(cout, cin * ks * ks, hw, wb, a_bits,
                              f"conv{i}_{r}"))
    gemms.append(Gemm(1000, 512, 1, 8, a_bits, "fc"))
    return gemms
