"""Cycle/energy cost models: Transitive Array + 5 baseline accelerators.

Replaces the paper's cycle-level simulator + ANT-derived baseline simulators
(Sec. 5.1). All designs share: 28 nm, 500 MHz, a DRAM-bandwidth roofline,
idealised double buffering (compute/DRAM overlap → time = max of the two).

The TA model is *driven by the real scoreboard statistics* of the workload's
actual (or sampled) TransRows — not an assumed density — so Fig. 9/10/12/13
reproductions inherit the faithful Alg.1/Alg.2 behaviour.

Array/PE configurations come straight from the paper's Tables 1-2.
"""
from __future__ import annotations

import dataclasses
import math
import numpy as np

from repro.core import energy as E
from repro.core import bitslice
from repro.core.patterns import tile_stats
from repro.core.scoreboard import dynamic_scoreboard

__all__ = ["Gemm", "AcceleratorModel", "TransitiveArrayModel",
           "BitFusionModel", "AntModel", "OliveModel", "TenderModel",
           "BitVertModel", "RunResult", "sample_subtile_stats", "BASELINES"]

DRAM_GBPS = 128.0          # off-chip bandwidth shared by all designs


@dataclasses.dataclass(frozen=True)
class Gemm:
    """One GEMM workload: out(n, m) += W(n, k) @ X(k, m)."""
    n: int
    k: int
    m: int
    w_bits: int = 8
    a_bits: int = 8
    name: str = ""

    @property
    def macs(self) -> int:
        return self.n * self.k * self.m

    @property
    def dram_bytes(self) -> int:
        return (self.n * self.k * self.w_bits // 8
                + self.k * self.m * self.a_bits // 8
                + self.n * self.m * 2)          # 16-bit requantized output


@dataclasses.dataclass(frozen=True)
class RunResult:
    name: str
    cycles: float
    seconds: float
    energy: E.EnergyTally

    def speedup_over(self, other: "RunResult") -> float:
        return other.seconds / self.seconds


def _dram_cycles(g: Gemm) -> float:
    return g.dram_bytes / (DRAM_GBPS * 1e9) * E.FREQ_HZ


class AcceleratorModel:
    """Base: compute-roofline vs DRAM-roofline with per-design hooks."""
    name = "base"

    def compute_cycles(self, g: Gemm) -> float:
        raise NotImplementedError

    def pe_energy_pj(self, g: Gemm) -> float:
        raise NotImplementedError

    def buffer_energy_pj(self, g: Gemm) -> float:
        # Output-stationary systolic reuse: weights re-read per m-tile,
        # activations per n-tile, outputs accumulated on-chip.
        tn, tm = self.tile_nm()
        w_reads = g.n * g.k * (g.w_bits / 8) * math.ceil(g.m / tm)
        a_reads = g.k * g.m * (g.a_bits / 8) * math.ceil(g.n / tn)
        out_rw = 2 * g.n * g.m * 4
        return (w_reads + a_reads + out_rw) * E.PJ_SRAM_BYTE

    def tile_nm(self) -> tuple[int, int]:
        raise NotImplementedError

    def run_gemm(self, g: Gemm) -> RunResult:
        cyc = max(self.compute_cycles(g), _dram_cycles(g))
        sec = cyc / E.FREQ_HZ
        tally = E.EnergyTally(
            pe=self.pe_energy_pj(g),
            buffer=self.buffer_energy_pj(g),
            dram=g.dram_bytes * E.PJ_DRAM_BYTE,
            static=(E.MW_STATIC_CORE + E.MW_STATIC_DRAM) * 1e-3 * sec * 1e12)
        return RunResult(self.name, cyc, sec, tally)

    def run(self, gemms: list[Gemm]) -> RunResult:
        total_c, total_s, tally = 0.0, 0.0, E.EnergyTally()
        for g in gemms:
            r = self.run_gemm(g)
            total_c += r.cycles
            total_s += r.seconds
            tally = tally + r.energy
        return RunResult(self.name, total_c, total_s, tally)


# --------------------------------------------------------------------------
# Baselines (array shapes & PE types from Table 2)
# --------------------------------------------------------------------------

class _UniformPEModel(AcceleratorModel):
    """Dense PE array; throughput scales with precision decomposition."""
    rows = cols = 0
    pe_bits = 8            # native PE operand width

    def _decompose(self, g: Gemm) -> float:
        """Cycles per MAC from splitting operands onto native-width PEs."""
        return (math.ceil(max(g.w_bits, self.pe_bits) / self.pe_bits)
                * math.ceil(max(g.a_bits, self.pe_bits) / self.pe_bits))

    def macs_per_cycle(self, g: Gemm) -> float:
        return self.rows * self.cols / self._decompose(g)

    def compute_cycles(self, g: Gemm) -> float:
        # ceil-tiled utilisation
        eff_n = math.ceil(g.n / self.rows) * self.rows
        eff_m = math.ceil(g.m / self.cols) * self.cols
        return eff_n * g.k * eff_m / (self.rows * self.cols) * self._decompose(g)

    def _pe_mac_pj(self) -> float:
        return {4: E.PJ_MAC_4, 8: E.PJ_MAC_8, 16: E.PJ_MAC_16}[self.pe_bits]

    def pe_energy_pj(self, g: Gemm) -> float:
        return g.macs * self._decompose(g) * self._pe_mac_pj()

    def tile_nm(self) -> tuple[int, int]:
        return self.rows, self.cols


class BitFusionModel(_UniformPEModel):
    """Bit-level composable 8-bit PEs, 28x32 (Table 2)."""
    name = "bitfusion"
    rows, cols, pe_bits = 28, 32, 8


class AntModel(_UniformPEModel):
    """Adaptive 4-bit datatype PEs, 36x64; 8-bit ops decompose 2x2."""
    name = "ant"
    rows, cols, pe_bits = 36, 64, 4


class OliveModel(_UniformPEModel):
    """Outlier-victim-pair 4-bit PEs, 32x48; outliers absorbed in-place."""
    name = "olive"
    rows, cols, pe_bits = 32, 48, 4


class TenderModel(_UniformPEModel):
    """4-bit PEs, 30x48; no mixed precision (4-bit only, Sec. 5.4)."""
    name = "tender"
    rows, cols, pe_bits = 30, 48, 4


class BitVertModel(_UniformPEModel):
    """BBS bi-directional bit-sparsity, 16x30 8-bit PEs, >=50% bit skip.

    ``overhead`` (bit-column imbalance + binary-pruning bookkeeping) is
    calibrated so BitVert lands at its own reported 1.9x over Olive on LLMs
    (quoted in Sec. 5.5), instead of the idealised 2x-skip upper bound.
    """
    name = "bitvert"
    rows, cols, pe_bits = 16, 30, 8
    bit_sparsity = 0.5
    overhead = 1.31

    def _decompose(self, g: Gemm) -> float:
        act = math.ceil(max(g.a_bits, 8) / 8)
        wgt = math.ceil(max(g.w_bits, 8) / 8)
        return act * wgt * (1.0 - self.bit_sparsity) * self.overhead


# --------------------------------------------------------------------------
# Transitive Array (Table 1: 6 units, T=8, 256 TransRows, 8x32 PPE/APE)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SubtileProfile:
    """Mean per-sub-tile statistics measured from real scoreboards."""
    ppe_cycles: float        # max-lane PPE ops (incl. outlier tail)
    ape_cycles: float        # max-lane APE ops
    ppe_ops: float           # total PPE adds (energy)
    ape_ops: float           # total APE accumulations (energy)
    n_rows: int              # TransRows per sub-tile (<= 256)

    @property
    def cycles(self) -> float:
        sb = self.n_rows / 8 + math.log2(max(self.n_rows, 2)) ** 2 / 8
        return max(self.ppe_cycles, self.ape_cycles, sb)


def sample_subtile_stats(w: np.ndarray, w_bits: int, t: int = 8,
                         n_rows: int = 256, max_tiles: int = 512,
                         seed: int = 0) -> SubtileProfile:
    """Bit-slice (a sample of) a weight matrix into 256-TransRow sub-tiles
    and run the dynamic scoreboard on them (Sec. 5.1: we extract real
    tensors; sampling keeps the model tractable; stats concentrate fast)."""
    rows = bitslice.transrow_matrix(np.asarray(w), w_bits, t)   # (S, N, K/t)
    flat = rows.transpose(2, 1, 0).reshape(-1)                   # col-major rows
    n_sub = len(flat) // n_rows
    tiles = flat[:n_sub * n_rows].reshape(n_sub, n_rows)
    if n_sub > max_tiles:
        sel = np.random.default_rng(seed).choice(n_sub, max_tiles, replace=False)
        tiles = tiles[sel]
    st = tile_stats(dynamic_scoreboard(tiles, t))
    return SubtileProfile(
        ppe_cycles=float(st.ppe_cycles.mean()),
        ape_cycles=float(st.ape_cycles.mean()),
        ppe_ops=float(st.ppe_ops.mean()),
        ape_ops=float(st.ape_ops.mean()),
        n_rows=n_rows)


def random_subtile_profile(w_bits: int, t: int = 8, n_rows: int = 256,
                           tiles: int = 256, seed: int = 0) -> SubtileProfile:
    """Profile on uniform random data (Sec. 5.9's random baseline)."""
    rng = np.random.default_rng(seed)
    w = rng.integers(-(1 << (w_bits - 1)), 1 << (w_bits - 1),
                     size=(tiles * n_rows // w_bits, t))
    return sample_subtile_stats(w, w_bits, t, n_rows, max_tiles=tiles)


class TransitiveArrayModel(AcceleratorModel):
    """6 TA units; each sub-tile = 256 TransRows x T=8 k-cols x 32 m-cols."""
    name = "transarray"
    units = 6
    t = 8
    m_tile = 32
    max_rows = 256

    def __init__(self, profile: SubtileProfile | None = None, w_bits: int = 8):
        self.w_bits = w_bits
        self.profile = profile or random_subtile_profile(w_bits)

    def _subtiles(self, g: Gemm) -> float:
        rows_per = self.max_rows // g.w_bits          # weight rows per sub-tile
        return (math.ceil(g.n / rows_per) * math.ceil(g.k / self.t)
                * math.ceil(g.m / self.m_tile))

    def compute_cycles(self, g: Gemm) -> float:
        # Sec. 4.5: PPE/APE split into halves for 4-bit activations (2x
        # throughput); 16-bit activations take 2 passes.
        act_scale = max(g.a_bits / 8.0, 0.5)
        return self._subtiles(g) * self.profile.cycles / self.units * act_scale

    def pe_energy_pj(self, g: Gemm) -> float:
        ns = self._subtiles(g)
        per = (self.profile.ppe_ops * self.m_tile * E.PJ_ADD_12
               + self.profile.ape_ops * self.m_tile * E.PJ_ADD_24)
        sb = self.profile.n_rows * 8 * E.PJ_ADD_8     # scoreboard table ops
        return ns * (per + sb)

    def buffer_energy_pj(self, g: Gemm) -> float:
        """Fig. 11: buffer traffic dominates TA's own breakdown.

        Prefix psums are 12-bit (2 B) in small distributed banks (REG cost);
        inputs broadcast through the Benes net; output partials accumulate in
        the double buffer (REG) and the 24-bit row results drain to the
        output SRAM once per sub-tile.
        """
        ns = self._subtiles(g)
        psum = (self.profile.ppe_ops + self.profile.ape_ops) * self.m_tile * 2
        outs_accum = (self.max_rows / self.w_bits) * self.m_tile * 8
        inputs = self.profile.ppe_ops * self.m_tile * 1
        weights = self.profile.n_rows * 1
        out_drain = (self.max_rows / self.w_bits) * self.m_tile * 4
        return ns * ((psum + outs_accum + inputs) * E.PJ_REG_BYTE
                     + (weights + out_drain) * E.PJ_SRAM_BYTE)

    def tile_nm(self) -> tuple[int, int]:
        return self.max_rows // self.w_bits, self.m_tile


BASELINES = {
    "bitfusion": BitFusionModel,
    "ant": AntModel,
    "olive": OliveModel,
    "tender": TenderModel,
    "bitvert": BitVertModel,
}


def core_area_mm2() -> dict[str, float]:
    """Computation-core areas (Table 2 reproduction)."""
    ta = (6 * (8 * 32) * (E.AREA_TA_PPE + E.AREA_TA_APE)
          + 6 * E.AREA_TA_NOC + E.AREA_TA_SCOREBOARD)
    return {
        "transarray": ta / 1e6,
        "bitfusion": 28 * 32 * E.AREA_BITFUSION_PE / 1e6,
        "ant": 36 * 64 * E.AREA_ANT_PE / 1e6,
        "olive": 32 * 48 * E.AREA_OLIVE_PE / 1e6,
        "bitvert": 16 * 30 * E.AREA_BITVERT_PE / 1e6,
        "tender": 30 * 48 * E.AREA_TENDER_PE / 1e6,
    }
