"""28 nm energy/area constants shared by the TA cost model and baselines.

Per-op energies follow Horowitz (ISSCC'14, 45 nm) scaled by ~0.7x to 28 nm;
SRAM/DRAM follow CACTI-7-class numbers at 28 nm. Absolute pJ values are
*modeled*; the reproduction target is the paper's speedup/energy **ratios**
(DESIGN.md §8.3). Area constants are taken directly from the paper's
Table 2 (they were synthesized with Synopsys DC at 28 nm).
"""
from __future__ import annotations

import dataclasses

# --- per-op dynamic energy (pJ), 28 nm -------------------------------------
PJ_ADD_8 = 0.021       # 8-bit int add
PJ_ADD_12 = 0.032      # 12-bit adder (TA PPE)
PJ_ADD_24 = 0.063      # 24-bit accumulator (TA APE)
PJ_ADD_32 = 0.070      # 32-bit add
PJ_MUL_8 = 0.140       # 8-bit int multiply
PJ_MUL_4 = 0.040       # 4-bit int multiply
PJ_MUL_16 = 0.560      # 16-bit int multiply
PJ_MAC_8 = PJ_MUL_8 + PJ_ADD_32
PJ_MAC_4 = PJ_MUL_4 + PJ_ADD_24
PJ_MAC_16 = PJ_MUL_16 + PJ_ADD_32

# --- memory (pJ per byte) ---------------------------------------------------
PJ_SRAM_BYTE = 0.62    # ~80KB-class on-chip buffer access
PJ_REG_BYTE = 0.08     # small distributed prefix-buffer bank access
PJ_DRAM_BYTE = 120.0   # off-chip DRAM (15 pJ/bit)

# --- static power (mW) ------------------------------------------------------
MW_STATIC_CORE = 45.0      # leak for the ~0.5 mm^2 core + 0.5 MB buffers
MW_STATIC_DRAM = 250.0     # DRAM background/refresh power; Fig. 11 credits
                           # TA's energy win largely to reduced DRAM static
FREQ_HZ = 500e6            # all designs evaluated at 500 MHz (Sec. 5.1)

# --- areas (um^2), straight from the paper's Table 2 ------------------------
AREA_TA_PPE = 50.3
AREA_TA_APE = 101.7
AREA_TA_NOC = 19520.0
AREA_TA_SCOREBOARD = 92507.0
AREA_BITFUSION_PE = 548.0
AREA_ANT_PE = 210.0
AREA_OLIVE_PE = 319.0
AREA_BITVERT_PE = 985.0
AREA_TENDER_PE = 329.0


@dataclasses.dataclass(frozen=True)
class EnergyTally:
    """Accumulated energy in pJ by component (Fig. 11 breakdown)."""
    pe: float = 0.0
    buffer: float = 0.0
    dram: float = 0.0
    static: float = 0.0

    @property
    def total(self) -> float:
        return self.pe + self.buffer + self.dram + self.static

    def __add__(self, o: "EnergyTally") -> "EnergyTally":
        return EnergyTally(self.pe + o.pe, self.buffer + o.buffer,
                           self.dram + o.dram, self.static + o.static)
