"""Bit-slicing of quantized integer matrices into binary TransRow planes.

The paper (Sec. 2.1-2.2) decomposes an S-bit 2's-complement integer matrix
``W (N, K)`` into S binary planes ``B_s (N, K)`` such that

    W = sum_s  sigma_s * 2^s * B_s,      sigma_{S-1} = -1, else +1.

Planes are then chunked along K into T-bit **TransRows** — unsigned integers
in [0, 2^T) — which are the fundamental unit of transitive sparsity.

Everything here is pure numpy/jnp, shape-static, and bit-exact.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

__all__ = [
    "bit_planes",
    "plane_signs",
    "reconstruct_from_planes",
    "pack_transrows",
    "unpack_transrows",
    "transrow_matrix",
]


def plane_signs(bits: int) -> np.ndarray:
    """Per-plane signed weights (+2^s, MSB gets -2^(S-1)) for 2's complement."""
    if bits < 2:
        raise ValueError(f"need >=2 bits for signed slicing, got {bits}")
    w = 2.0 ** np.arange(bits)
    signs = np.ones(bits)
    signs[-1] = -1.0
    return (signs * w).astype(np.int64)


def bit_planes(w: np.ndarray, bits: int) -> np.ndarray:
    """Slice an integer matrix into its binary planes.

    Args:
      w: integer array, values in [-2^(bits-1), 2^(bits-1)).
      bits: S, the quantized bit width.

    Returns:
      uint8 array of shape (bits,) + w.shape with entries in {0, 1};
      plane ``s`` holds bit ``s`` of the 2's-complement representation.
    """
    # widen first: narrow int dtypes (int8 weights) overflow the 2's
    # complement shift below under NumPy 2 scalar promotion
    w = np.asarray(w).astype(np.int64, copy=False)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if w.min(initial=0) < lo or w.max(initial=0) > hi:
        raise ValueError(f"values outside int{bits} range [{lo}, {hi}]")
    # 2's complement of negatives within `bits` bits.
    u = np.where(w < 0, w + (1 << bits), w).astype(np.uint32)
    planes = np.stack([(u >> s) & 1 for s in range(bits)]).astype(np.uint8)
    return planes


def reconstruct_from_planes(planes: np.ndarray, bits: int) -> np.ndarray:
    """Inverse of :func:`bit_planes` (int64, bit-exact)."""
    signs = plane_signs(bits)
    return np.tensordot(signs, planes.astype(np.int64), axes=(0, 0))


def pack_transrows(planes: np.ndarray, t: int) -> np.ndarray:
    """Pack binary planes into T-bit TransRow integers along the last axis.

    Args:
      planes: uint8 {0,1} array (..., K) with K divisible by ``t``.
      t: TransRow width T.

    Returns:
      uint32 array (..., K // t); element j encodes bits
      planes[..., j*t : (j+1)*t] with **bit i = column (j*t + i)**
      (column 0 is the least-significant bit).
    """
    k = planes.shape[-1]
    if k % t:
        raise ValueError(f"K={k} not divisible by T={t}")
    chunks = planes.reshape(planes.shape[:-1] + (k // t, t)).astype(np.uint32)
    weights = (1 << np.arange(t)).astype(np.uint32)
    return (chunks * weights).sum(-1).astype(np.uint32)


def unpack_transrows(rows: np.ndarray, t: int) -> np.ndarray:
    """Inverse of :func:`pack_transrows` → uint8 planes (..., K)."""
    rows = np.asarray(rows, dtype=np.uint32)
    bits = ((rows[..., None] >> np.arange(t, dtype=np.uint32)) & 1).astype(np.uint8)
    return bits.reshape(rows.shape[:-1] + (rows.shape[-1] * t,))


def transrow_matrix(w: np.ndarray, bits: int, t: int) -> np.ndarray:
    """Full pipeline: int matrix (N, K) → TransRows (bits, N, K//t) uint32.

    Axis 0 is the bit level (shift s); the paper's flattened (S*N, K//t)
    layout is a reshape of this.
    """
    return pack_transrows(bit_planes(w, bits), t)


# --- jnp variants (jit-safe, used inside model code) -----------------------

def bit_planes_jnp(w: jnp.ndarray, bits: int) -> jnp.ndarray:
    u = jnp.where(w < 0, w + (1 << bits), w).astype(jnp.uint32)
    return jnp.stack([(u >> s) & 1 for s in range(bits)]).astype(jnp.uint8)


def pack_transrows_jnp(planes: jnp.ndarray, t: int) -> jnp.ndarray:
    k = planes.shape[-1]
    chunks = planes.reshape(planes.shape[:-1] + (k // t, t)).astype(jnp.uint32)
    weights = (jnp.uint32(1) << jnp.arange(t, dtype=jnp.uint32))
    return (chunks * weights).sum(-1).astype(jnp.uint32)
