"""Core of the paper's contribution: transitive sparsity over bit-sliced GEMM.

Modules:
  bitslice   — S-bit 2's-complement ↔ binary planes ↔ T-bit TransRows
  hasse      — subset partial order tables (prefixes/suffixes/levels)
  scoreboard — faithful Alg.1/Alg.2 + balanced forest (static & dynamic SI)
  transitive — lossless transitive GEMM execution (bit-exact oracle)
  engine     — batched multi-tile plan/run engine (offline/online split)
  backend    — pluggable execution-backend registry (capabilities + plan/
               compile/execute lifecycle; replaces string-path dispatch)
  plancache  — LRU ExecutionPlan cache + precompile (serving amortisation)
  patterns   — ZR/TR/FR/PR classification, density & cycle statistics
  costmodel  — Transitive Array cycle/energy model (Tbl. 1/2 config)
  baselines  — BitFusion / ANT / Olive / Tender / BitVert analytic models
"""
from repro.core import (backend, bitslice, engine, hasse,  # noqa: F401
                        patterns, plancache, scoreboard, transitive)
