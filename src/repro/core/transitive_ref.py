"""Row-at-a-time transitive GEMM walker — the system's bit-exactness oracle.

This is the original (seed) execution path: one k-tile and one Hasse node
at a time, in plain Python loops, mirroring the hardware's per-node dataflow
(Fig. 8) as literally as possible:

  for each k-tile of width T:
    psum[node] = psum[prefix(node)] + sum(X rows of diff bits)   # PPE
    out[row]  += sign * 2^shift * psum[node(row)]                # APE + shift

It is deliberately slow and deliberately clear: every fast path in the
repo — the batched level-synchronous engine (core/engine.py), the Pallas
kernel (kernels/transitive_gemm.py, interpret mode on CPU) and the quant
integer-matmul path — is differentially tested against this walker *and*
against plain ``W.astype(i64) @ X.astype(i64)`` (the paper's lossless
claim, Sec. 2.1).

Do not optimise this module. Optimisations go in core/engine.py.
"""
from __future__ import annotations

import numpy as np

from repro.core import bitslice, hasse
from repro.core.scoreboard import dynamic_scoreboard, ScoreboardInfo

__all__ = ["transitive_gemm_ref", "execute_tile"]


def execute_tile(si: ScoreboardInfo, tile_idx: int, x_tile: np.ndarray) -> np.ndarray:
    """Compute psums (2^T, M) for one tile by walking the prefix forest.

    Args:
      si: scoreboard for a batch of tiles.
      tile_idx: which tile.
      x_tile: (T, M) integer input rows for this k-tile.

    Returns: (2^T, M) int64 psum table (only executed nodes are valid).
    """
    t = si.t
    size = 1 << t
    m = x_tile.shape[1]
    psum = np.zeros((size, m), dtype=np.int64)
    order = hasse.hamming_order(t)
    exec_counts = si.exec_counts[tile_idx]
    outlier = si.outlier[tile_idx]
    prefix = si.prefix[tile_idx]
    x64 = x_tile.astype(np.int64)
    for idx in order:
        if idx == 0 or exec_counts[idx] == 0:
            continue
        if outlier[idx]:
            # dispatched at the end via direct accumulation
            bits = [b for b in range(t) if (idx >> b) & 1]
            psum[idx] = x64[bits].sum(0)
            continue
        pre = int(prefix[idx])
        assert pre >= 0, f"executed node {idx} lacks a prefix"
        diff = idx ^ pre
        assert diff and hasse.is_prefix(pre, idx), (idx, pre)
        bits = [b for b in range(t) if (diff >> b) & 1]
        psum[idx] = psum[pre] + x64[bits].sum(0)
    return psum


def transitive_gemm_ref(w: np.ndarray, x: np.ndarray, bits: int, t: int,
                        max_distance: int = 4) -> np.ndarray:
    """Full transitive GEMM: int-S ``w (N, K)`` @ int ``x (K, M)`` → int64.

    Bit-slices w, builds a dynamic scoreboard per k-tile over all S*N
    TransRows of the tile, executes the forest, then shift-accumulates
    per-plane psums with 2's-complement signs.
    """
    w = np.asarray(w)
    x = np.asarray(x)
    n, k = w.shape
    assert x.shape[0] == k and k % t == 0
    rows = bitslice.transrow_matrix(w, bits, t)        # (S, N, K//t)
    signs = bitslice.plane_signs(bits)                 # (S,)
    out = np.zeros((n, x.shape[1]), dtype=np.int64)
    for j in range(k // t):
        tile_rows = rows[:, :, j].reshape(1, -1)       # one tile: S*N rows
        si = dynamic_scoreboard(tile_rows, t, max_distance)
        psum = execute_tile(si, 0, x[j * t:(j + 1) * t])
        vals = rows[:, :, j]                           # (S, N)
        out += (signs[:, None, None] * psum[vals]).sum(0)
    return out
