"""First-class execution backends: a pluggable registry for the online half
of the Transitive Array.

The paper splits execution into offline TransRow packing and online
multiplication-free GEMM; this repo grew four online strategies (dense
``int_dot``, the doubling-LUT ``lut``/``pallas`` kernels, and the
Scoreboard-forest ``engine`` family). They used to be selected by string
``if/elif`` chains duplicated across quant/qlinear.py, launch/serve.py and
benchmarks/bench_kernel.py. This module replaces the strings with declared
objects:

  * :class:`TransitiveBackend` — the protocol every execution strategy
    implements: capability flags (``device_resident``, ``supports_groups``,
    ``supports_jit``, ``needs_plan``, ``cpu_ok``) plus a uniform lifecycle
    ``plan(w, cfg) -> ExecutionPlan | None`` (offline, weight-only),
    ``compile(plan, mesh=None, specs=None) -> DevicePlan | None`` (lowering
    + optional sharding), ``execute(x, w, plan, dplan, cfg) -> int32``
    (the online hot path).
  * :class:`EngineConfig` — the engine-side knobs ``(w_bits, t, groups)``
    as one frozen dataclass instead of loose kwargs threaded through the
    stack.
  * a process-level registry (:func:`register_backend`,
    :func:`get_backend`, :func:`list_backends`) so serving, benchmarks and
    tests enumerate backends instead of hardcoding choice lists, and a
    custom backend drops in without touching the dispatch sites.

Two hooks the ROADMAP names next are part of the protocol rather than
bolted on: ``compile(..., mesh=, specs=)`` threads ``PartitionSpec``s onto
the (possibly stacked) :class:`~repro.core.engine.DevicePlan` leaves —
shard-ready plans for multi-device serving (:func:`shard_device_plan`) —
and the device lowering persists across processes tagged with its backend
(``ExecutionPlan.save(..., device=, backend=)`` /
``ExecutionPlan.load_bundle``).

``execute`` contract (all integer, all bit-exact with the ``int_dot``
int32 accumulator):

  * ungrouped (``cfg.groups == 1``): ``x (..., K) × w (N, K) -> (..., N)``
  * grouped   (``cfg.groups == G``): ``x (..., G, g) × w (N, G, g) ->
    (..., G, N)`` per-group partial sums (the caller rescales in the
    epilogue).

Run ``python -m repro.core.backend`` to print the registry; ``--cpu``
restricts to backends the CPU runner can satisfy (the CI serve-smoke loop
uses this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.engine import (DEVICE_DATA_FIELDS, DevicePlan, ExecutionPlan,
                               compile_plan, compile_plans, run_device_jit)

__all__ = ["EngineConfig", "TransitiveBackend", "register_backend",
           "unregister_backend", "get_backend", "list_backends",
           "shard_device_plan"]


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """The engine-side execution signature as one object.

    Replaces the loose ``(w_bits, T, groups)`` kwargs that used to thread
    through qlinear -> plancache -> engine. ``groups`` is the number of
    quantization groups concatenated along K (1 = per-channel).
    """
    w_bits: int = 8
    t: int = 8                 # TransRow width
    groups: int = 1

    @classmethod
    def from_quant(cls, qcfg: Any, groups: int = 1) -> "EngineConfig":
        """Build from a ``QuantConfig``-shaped object (``w_bits`` +
        ``transrow_t`` attributes)."""
        return cls(w_bits=qcfg.w_bits, t=qcfg.transrow_t, groups=groups)

    def key(self) -> tuple[int, int, int]:
        return (int(self.w_bits), int(self.t), int(self.groups))


CAPABILITY_FLAGS = ("device_resident", "supports_groups", "supports_jit",
                    "needs_plan", "cpu_ok")


class TransitiveBackend:
    """Base class / protocol for one online execution strategy.

    Capability flags (class attributes — declare, don't imply):

    ``device_resident``
        ``execute`` is pure JAX on device data; the lowered jaxpr contains
        no host callback. Device-resident backends that also ``needs_plan``
        consume a :class:`DevicePlan` (the ``dplan`` argument).
    ``supports_groups``
        ``execute`` accepts grouped inputs (``cfg.groups > 1``).
    ``supports_jit``
        ``execute`` composes with ``jax.jit`` (host-callback backends
        qualify via ``pure_callback``).
    ``needs_plan``
        the strategy has an offline weight-only half (:meth:`plan`); serving
        should precompile through :class:`~repro.core.plancache.PlanCache`.
    ``cpu_ok``
        the CPU runner can satisfy this backend (Pallas kernels via
        interpret mode count). CI uses this to skip accelerator-only
        backends.

    ``lint_exempt`` tags which tracelint rules (repro.analysis —
    ``list_rules()`` names) do NOT apply to this backend, with a reason
    per tag in the class docstring. The lint gate runs every other rule
    against the backend's serving programs; an exemption is a declared
    capability, not an escape hatch — e.g. the host ``engine`` oracle is
    exempt from ``no-host-callback`` because being a callback is its
    contract.
    """
    name: str = ""
    device_resident: bool = False
    supports_groups: bool = True
    supports_jit: bool = True
    needs_plan: bool = False
    cpu_ok: bool = True
    lint_exempt: frozenset[str] = frozenset()

    # -- lifecycle ---------------------------------------------------------
    def plan(self, w: np.ndarray, cfg: EngineConfig) -> ExecutionPlan | None:
        """Offline half: weight-only schedule for the full 2-D (N, K)
        weight (grouped layers pass all groups concatenated along K).
        Backends without an offline half return None."""
        return None

    def compile(self, plan, mesh=None, specs=None) -> DevicePlan | None:
        """Lower ``plan`` (one :class:`ExecutionPlan`, or a sequence of
        same-signature plans -> one stacked :class:`DevicePlan`) to
        device-resident index arrays. With ``mesh=`` the leaves are placed
        with the given ``PartitionSpec``s (:func:`shard_device_plan`) —
        shard-ready plans for multi-device serving. Backends without a
        device lowering return None."""
        return None

    def plan_specs(self, mesh):
        """How this backend's DevicePlan leaves are placed on ``mesh``.

        The serve path (``plancache.attach_device_plans`` /
        ``Model.attach_device_plans``) consults this when the caller gives
        a mesh but no explicit ``specs`` — the capability-keyed default
        placement. The base default replicates (``None``): plans are small
        index arrays, and data-parallel decode needs every device to hold
        every layer's plan. A backend whose lowering is sharded (say a TPU
        forest kernel splitting output rows over ``"model"``) overrides
        this to return a single ``PartitionSpec`` or a
        ``{leaf-field: PartitionSpec}`` mapping
        (:func:`shard_device_plan`'s forms)."""
        return None

    def execute(self, x: jnp.ndarray, w: jnp.ndarray,
                plan: ExecutionPlan | None, dplan: DevicePlan | None,
                cfg: EngineConfig) -> jnp.ndarray:
        """Online half — the integer GEMM (see the module docstring for the
        shape contract). Must be bit-exact with ``int_dot``'s int32
        accumulator."""
        raise NotImplementedError

    # -- introspection -----------------------------------------------------
    def capabilities(self) -> dict[str, bool]:
        return {f: bool(getattr(self, f)) for f in CAPABILITY_FLAGS}

    def lint_profile(self) -> dict[str, bool]:
        """rule name -> applies-to-this-backend, over the tracelint rule
        registry (repro.analysis). The lint driver consults
        ``lint_exempt`` directly; this is the introspection twin of
        :meth:`capabilities` for reports and the registry CLI."""
        from repro.analysis import list_rules
        return {r: r not in self.lint_exempt for r in list_rules()}

    def __repr__(self) -> str:
        caps = ", ".join(f for f in CAPABILITY_FLAGS if getattr(self, f))
        return f"{type(self).__name__}(name={self.name!r}, {caps})"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, TransitiveBackend] = {}


def register_backend(backend: TransitiveBackend, *,
                     replace: bool = False) -> TransitiveBackend:
    """Register ``backend`` under ``backend.name``.

    Duplicate names are a loud error unless ``replace=True`` — two backends
    silently shadowing each other is exactly the failure mode string
    dispatch had. Returns the backend (decorator-friendly)."""
    name = getattr(backend, "name", "")
    if not name or not isinstance(name, str):
        raise ValueError(f"backend must declare a non-empty string name, "
                         f"got {name!r}")
    if name in _REGISTRY and not replace:
        raise ValueError(
            f"backend '{name}' is already registered "
            f"({_REGISTRY[name]!r}); pass replace=True to override")
    _REGISTRY[name] = backend
    return backend


def unregister_backend(name: str) -> TransitiveBackend:
    """Remove a backend (tests / plugin teardown). Returns the removed
    backend; KeyError (with the valid names) if absent."""
    if name not in _REGISTRY:
        raise KeyError(_unknown_msg(name))
    return _REGISTRY.pop(name)


def list_backends() -> tuple[str, ...]:
    """Registered backend names, in registration order (stable for
    parametrized tests and CLI choice lists)."""
    return tuple(_REGISTRY)


def _unknown_msg(name) -> str:
    return (f"unknown backend {name!r}; registered backends: "
            f"{', '.join(sorted(_REGISTRY))}")


def get_backend(name) -> TransitiveBackend:
    """Resolve ``name`` to a registered backend.

    Accepts a registry name, a :class:`TransitiveBackend` instance (returned
    as-is), or any object with a ``backend_name()`` method / ``backend``
    attribute (a ``QuantConfig`` works — including its deprecated ``path=``
    shim). Unknown names raise ``KeyError`` listing the valid ones."""
    if isinstance(name, TransitiveBackend):
        return name
    if not isinstance(name, str):
        resolver = getattr(name, "backend_name", None)
        if callable(resolver):
            name = resolver()
        elif isinstance(getattr(name, "backend", None), str):
            name = name.backend
    try:
        return _REGISTRY[name]
    except (KeyError, TypeError):
        raise KeyError(_unknown_msg(name)) from None


# ---------------------------------------------------------------------------
# Sharding hook: PartitionSpecs onto DevicePlan leaves
# ---------------------------------------------------------------------------

def shard_device_plan(dplan: DevicePlan, mesh, specs=None) -> DevicePlan:
    """Place every :class:`DevicePlan` leaf on ``mesh`` under ``specs``.

    ``specs`` is ``None`` (replicate everywhere — the safe default for
    plans, which are small index arrays), a single ``PartitionSpec``
    applied to every leaf (e.g. ``P("data")`` to shard the stacked
    leading axis of scan-stacked plans), or a mapping from leaf field
    name (``level_src`` ...) to spec, missing fields replicated. Leaf
    values are unchanged — only placement — so a sharded plan stays
    bit-exact with its host twin."""
    from jax.sharding import NamedSharding, PartitionSpec

    if specs is None:
        specs = PartitionSpec()
    if isinstance(specs, PartitionSpec):
        specs = {f: specs for f in DEVICE_DATA_FIELDS}
    elif isinstance(specs, Mapping):
        bad = set(specs) - set(DEVICE_DATA_FIELDS)
        if bad:
            raise ValueError(f"unknown DevicePlan leaf fields {sorted(bad)}; "
                             f"valid: {list(DEVICE_DATA_FIELDS)}")
    else:
        raise TypeError("specs must be None, a PartitionSpec, or a "
                        "{leaf-field: PartitionSpec} mapping")
    placed = {
        f: jax.device_put(
            getattr(dplan, f),
            NamedSharding(mesh, specs.get(f, PartitionSpec())))
        for f in DEVICE_DATA_FIELDS}
    return dataclasses.replace(dplan, **placed)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

class IntDotBackend(TransitiveBackend):
    """Dense int8 ``dot_general`` (int32 accumulation) — the MXU-native
    execution; the bit-exactness reference for every other backend."""
    name = "int_dot"
    device_resident = True

    def execute(self, x, w, plan, dplan, cfg):
        if cfg.groups > 1:
            return jnp.einsum("...gi,ngi->...gn", x, w,
                              preferred_element_type=jnp.int32)
        return jax.lax.dot_general(
            x, w, (((x.ndim - 1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)


class LutBackend(TransitiveBackend):
    """Pure-jnp dense doubling-LUT transitive execution (kernels/ref.py) —
    the paper's result-reuse dataflow in software, data-independent."""
    name = "lut"
    device_resident = True

    def execute(self, x, w, plan, dplan, cfg):
        from repro.kernels import ref
        if cfg.groups > 1:
            return ref.transitive_matmul_grouped_ref(x, w, cfg.w_bits, cfg.t)
        return ref.transitive_matmul_ref(x, w, cfg.w_bits, cfg.t)


class PallasLutBackend(TransitiveBackend):
    """The doubling-LUT schedule as a Pallas TPU kernel
    (kernels/transitive_gemm.py); interpret mode on CPU."""
    name = "pallas"
    device_resident = True

    def execute(self, x, w, plan, dplan, cfg):
        from repro.kernels import ops
        if cfg.groups > 1:
            return ops.transitive_gemm_grouped(x, w, w_bits=cfg.w_bits,
                                               t=cfg.t)
        return ops.transitive_gemm(x, w, w_bits=cfg.w_bits, t=cfg.t)


class EngineHostBackend(TransitiveBackend):
    """The batched multi-tile Scoreboard engine (core/engine.py) on the
    host via ``pure_callback`` — the faithful forest dataflow, kept as the
    oracle next to core/transitive_ref.py. A plan resolved at dispatch
    time (the protocol's ``plan`` argument) is executed run-only with no
    further cache traffic; with ``plan=None`` (the weight was a tracer)
    the callback resolves it from the process plan cache per call.

    ``lint_exempt``: being a ``pure_callback`` is this backend's contract
    (it exists to differential-test the device paths), so
    ``no-host-callback`` does not apply to its serving programs."""
    name = "engine"
    needs_plan = True
    lint_exempt = frozenset({"no-host-callback"})

    def plan(self, w, cfg):
        from repro.core import plancache
        return plancache.default_cache().get_or_build(
            np.asarray(w), cfg, backend=self.name)

    def _gemm(self, plan, qw2, flat, cfg):
        """flat (B, K) int64 -> the engine's (N, [G,] B) layout."""
        if plan is not None:
            from repro.core.engine import BatchedTransitiveEngine
            return BatchedTransitiveEngine(bits=plan.bits,
                                           t=plan.t).run(plan, flat.T)
        from repro.core import plancache
        return plancache.default_cache().run(qw2, flat.T, cfg,
                                             backend=self.name)

    def execute(self, x, w, plan, dplan, cfg):
        from repro import jax_compat
        if plan is not None and (plan.bits, plan.t,
                                 plan.groups) != cfg.key():
            raise ValueError(
                f"plan signature (bits, t, groups)="
                f"{(plan.bits, plan.t, plan.groups)} does not match the "
                f"execute config {cfg.key()}")
        if cfg.groups > 1:
            n, n_groups, g = w.shape
            out = jax.ShapeDtypeStruct(x.shape[:-1] + (n,), jnp.int32)

            def host(xg_np, wg_np):
                # shape-agnostic: under vmap the callback sees extra
                # leading axes (size-1 on the unmapped weight with
                # vmap_method="expand_dims")
                qw2 = np.asarray(wg_np).reshape(wg_np.shape[-3],
                                                n_groups * g)
                flat = np.asarray(xg_np, np.int64).reshape(-1, n_groups * g)
                part = self._gemm(plan, qw2, flat, cfg)        # (N, G, M)
                return (part.transpose(2, 1, 0)
                        .reshape(xg_np.shape[:-1] + (n,)).astype(np.int32))

            return jax_compat.pure_callback(host, out, x, w,
                                            vmap_method="expand_dims")

        out = jax.ShapeDtypeStruct(x.shape[:-1] + (w.shape[0],), jnp.int32)

        def host(qx_np, qw_np):
            qw2 = np.asarray(qw_np).reshape(qw_np.shape[-2:])
            flat = np.asarray(qx_np, np.int64).reshape(-1, qx_np.shape[-1])
            y = self._gemm(plan, qw2, flat, cfg).T
            return (y.reshape(qx_np.shape[:-1] + (qw2.shape[0],))
                    .astype(np.int32))

        return jax_compat.pure_callback(host, out, x, w,
                                        vmap_method="expand_dims")


class EngineJitBackend(TransitiveBackend):
    """The planned forest executed device-resident (DevicePlan +
    ``run_device``): pure jnp gathers under jit, zero host callbacks."""
    name = "engine_jit"
    needs_plan = True
    device_resident = True

    def plan(self, w, cfg):
        from repro.core import plancache
        return plancache.default_cache().get_or_build(
            np.asarray(w), cfg, backend=self.name)

    def compile(self, plan, mesh=None, specs=None):
        if isinstance(plan, ExecutionPlan):
            dplan = compile_plan(plan)
        elif isinstance(plan, Sequence):
            dplan = compile_plans(list(plan))
        else:
            raise TypeError(f"plan must be an ExecutionPlan or a sequence "
                            f"of them, got {type(plan).__name__}")
        if mesh is not None:
            dplan = shard_device_plan(dplan, mesh, specs)
        return dplan

    def _forest(self, dplan, flat):
        """flat int32 (K, B) activations -> (N, B) / (N, G, B)."""
        return run_device_jit(dplan, flat)

    def execute(self, x, w, plan, dplan, cfg):
        if dplan is None:
            if plan is None:
                raise ValueError(
                    f"backend '{self.name}' is device-resident: execute "
                    f"needs a compiled DevicePlan (or an ExecutionPlan to "
                    f"lower) — compile with backend.compile(plan) or serve "
                    f"through plancache.attach_device_plans")
            dplan = self.compile(plan)
        if cfg.groups > 1:
            n_groups, g = x.shape[-2], x.shape[-1]
            flat = x.reshape(-1, n_groups * g).astype(jnp.int32).T
            y = self._forest(dplan, flat)                  # (N, G, B)
            return y.transpose(2, 1, 0).reshape(x.shape[:-1] + (dplan.n,))
        flat = x.reshape(-1, x.shape[-1]).astype(jnp.int32).T    # (K, B)
        y = self._forest(dplan, flat)                            # (N, B)
        return y.T.reshape(x.shape[:-1] + (dplan.n,))


class EnginePallasBackend(EngineJitBackend):
    """The same DevicePlan forest as a Pallas kernel
    (kernels/transitive_forest.py; interpret on CPU)."""
    name = "engine_pallas"

    def _forest(self, dplan, flat):
        from repro.kernels import transitive_forest
        return transitive_forest.transitive_forest(dplan, flat)


for _b in (IntDotBackend(), LutBackend(), PallasLutBackend(),
           EngineHostBackend(), EngineJitBackend(), EnginePallasBackend()):
    register_backend(_b)
del _b


if __name__ == "__main__":
    import argparse
    # runpy executes this file as __main__ with its own module globals;
    # consult the canonical module so the registry printed is the one
    # every import site (and any plugin registration) actually uses
    from repro.core import backend as _canonical
    ap = argparse.ArgumentParser(
        description="List registered Transitive Array execution backends")
    ap.add_argument("--cpu", action="store_true",
                    help="only names the CPU runner can satisfy, one per "
                    "line (the CI serve-smoke loop consumes this)")
    args = ap.parse_args()
    for n in _canonical.list_backends():
        b = _canonical.get_backend(n)
        if args.cpu:
            if b.cpu_ok:
                print(n)
        else:
            print(f"{n:16s} {b.capabilities()}")
