"""Faithful Scoreboard (paper Sec. 3): Alg. 1 forward, Alg. 2 backward, forest.

The Scoreboard turns an observed multiset of T-bit TransRows into an
execution plan over the Hasse graph:

  1. Hamming-order sort (Sec. 3.1) — we traverse nodes level-by-level.
  2. Forward pass (Alg. 1)  — propagate candidate prefixes with distances
     1..4 down the covering edges; present nodes reset the distance.
  3. Backward pass (Alg. 2) — nodes with Count>0 and Distance>1 pick the
     first relay prefix from the smallest-distance prefix bitmap and
     materialise the relay as a bridge (Count := 1, a "TR" node).
  4. Balanced forest (Sec. 2.4/Fig. 5-5) — distance-1 nodes choose, among
     their candidate prefixes, the lane with the least workload; lanes are
     rooted at the T level-1 nodes.

Everything is vectorised across an arbitrary leading ``tiles`` axis so that
whole-tensor (static) and per-sub-tile (dynamic) scoreboards share one
implementation. Plain numpy — this is the *model* of the hardware unit; the
TPU execution path lives in kernels/.
"""
from __future__ import annotations

import dataclasses
import numpy as np

from repro.core import hasse

__all__ = ["ScoreboardInfo", "dynamic_scoreboard", "static_scoreboard",
           "static_tile_stats", "MAX_DISTANCE", "INF"]

MAX_DISTANCE = 4      # paper: prefixes with distance < 4; >=4 are outliers
INF = 1 << 30


@dataclasses.dataclass
class ScoreboardInfo:
    """Scoreboard Information (SI) for a batch of tiles (Fig. 5 step 6)."""
    t: int                      # TransRow width T
    n_rows: int                 # TransRows per tile
    counts: np.ndarray          # (tiles, 2^T) int32 — original row counts
    exec_counts: np.ndarray     # (tiles, 2^T) int32 — counts after bridging
    bridge: np.ndarray          # (tiles, 2^T) bool  — TR nodes (materialised)
    distance: np.ndarray        # (tiles, 2^T) int32 — final distance (INF = none)
    prefix: np.ndarray          # (tiles, 2^T) int32 — selected prefix node (-1: root/none)
    lane: np.ndarray            # (tiles, 2^T) int32 — lane id (-1: unassigned)
    outlier: np.ndarray         # (tiles, 2^T) bool  — present, distance >= MAX_DISTANCE
    wl_ppe: np.ndarray          # (tiles, T) int64   — per-lane PPE ops
    wl_ape: np.ndarray          # (tiles, T) int64   — per-lane APE ops

    @property
    def tiles(self) -> int:
        return self.counts.shape[0]

    @property
    def present(self) -> np.ndarray:
        p = self.counts > 0
        p[:, 0] = False
        return p

    @property
    def executed(self) -> np.ndarray:
        """Nodes that occupy a PPE slot (present or bridge, excl. node 0)."""
        e = (self.exec_counts > 0) & ~self.outlier
        e[:, 0] = False
        return e


# De Bruijn multiply-shift lowest-set-bit: exact in integer arithmetic, so
# prefix selection cannot drift with float log2 rounding at larger T.
_DEBRUIJN32 = np.uint32(0x077CB531)
_DEBRUIJN_IDX = np.empty(32, dtype=np.int64)
for _i in range(32):
    _DEBRUIJN_IDX[(((1 << _i) * 0x077CB531) & 0xFFFFFFFF) >> 27] = _i
del _i


def _first_set_bit(bm: np.ndarray) -> np.ndarray:
    """Lowest set bit index of each nonzero entry ("first available" prefix)."""
    b32 = bm.astype(np.uint32)
    lsb = b32 & (~b32 + np.uint32(1))       # isolate lowest set bit
    idx = _DEBRUIJN_IDX[(lsb * _DEBRUIJN32) >> np.uint32(27)]
    return np.where(b32 != 0, idx, -1)


def _node_counts(rows: np.ndarray, t: int) -> np.ndarray:
    """Per-tile histogram over 2^T node values. rows: (tiles, n) uint."""
    tiles, n = rows.shape
    size = 1 << t
    offs = (np.arange(tiles, dtype=np.int64)[:, None] * size)
    flat = np.bincount((rows.astype(np.int64) + offs).ravel(),
                       minlength=tiles * size)
    return flat.reshape(tiles, size).astype(np.int32)


def dynamic_scoreboard(rows: np.ndarray, t: int,
                       max_distance: int = MAX_DISTANCE) -> ScoreboardInfo:
    """Build per-tile Scoreboard Information (the dynamic SI, Sec. 3.4).

    Args:
      rows: (tiles, n) uint array of TransRow values in [0, 2^T).
      t: TransRow width.
      max_distance: paper's outlier threshold (4).

    Returns: ScoreboardInfo batched over tiles.
    """
    rows = np.atleast_2d(np.asarray(rows))
    tiles, n_rows = rows.shape
    size = 1 << t
    counts = _node_counts(rows, t)
    levels = hasse.levels(t)
    order = hasse.hamming_order(t)
    cov_pre = hasse.covering_prefixes(t)    # (2^T, T)
    cov_suf = hasse.covering_suffixes(t)    # (2^T, T)

    # Prefix bitmaps: PB[tile, node, d-1] is a T-bit mask; bit i set means
    # "node with bit i cleared relays a path of distance d" (Fig. 6).
    pb = np.zeros((tiles, size, max_distance), dtype=np.uint32)
    dist = np.full((tiles, size), INF, dtype=np.int64)
    dist[:, 0] = 0

    # ---- Forward pass (Alg. 1) ------------------------------------------
    for idx in order:
        d = dist[:, idx]
        # Line 7: nodes at distance >= max_d (and not root) neither relay
        # nor receive a path — they are outliers.
        alive = (d < max_distance) | (idx == 0)
        if not alive.any():
            continue
        present = counts[:, idx] > 0
        eff = np.where(present | (idx == 0), 0, d)        # Line 8
        sufs = cov_suf[idx]
        set_bits = np.nonzero(sufs >= 0)[0]
        for b in set_bits:                                 # Lines 9-10
            sfx = int(sufs[b])
            # relayed distance eff+1 must fit a bitmap slot (<= max_d)
            for dval in range(1, max_distance + 1):
                m = alive & (eff == dval - 1)
                if not m.any():
                    continue
                pb[m, sfx, dval - 1] |= np.uint32(1 << b)
                dist[m, sfx] = np.minimum(dist[m, sfx], dval)   # Line 13

    outlier = (counts > 0) & (dist >= max_distance)
    outlier[:, 0] = False

    # ---- Backward pass (Alg. 2) -----------------------------------------
    exec_counts = counts.copy()
    bridge = np.zeros((tiles, size), dtype=bool)
    prefix = np.full((tiles, size), -1, dtype=np.int64)
    tidx = np.arange(tiles)
    for idx in order[::-1]:
        if idx == 0:
            continue
        d = dist[:, idx]
        need = (exec_counts[:, idx] > 0) & (d > 1) & (d < max_distance)
        if not need.any():
            continue
        sel = np.nonzero(need)[0]
        bm = pb[sel, idx, d[sel] - 1]                      # Line 7: first PB
        b = _first_set_bit(bm)
        ok = b >= 0
        sel, b = sel[ok], b[ok]
        relay = int(idx) & ~(1 << b)                       # 1->0 bit flip
        newly = exec_counts[sel, relay] == 0
        bridge[sel[newly], relay[newly]] = True            # TR node
        exec_counts[sel[newly], relay[newly]] = 1          # Count := 1 (L.8-10)
        prefix[sel, idx] = relay
    del tidx

    # ---- Balanced forest (lane assignment) -------------------------------
    lane = np.full((tiles, size), -1, dtype=np.int64)
    wl_ppe = np.zeros((tiles, t), dtype=np.int64)
    wl_ape = np.zeros((tiles, t), dtype=np.int64)
    for idx in order:
        if idx == 0:
            continue
        exe = (exec_counts[:, idx] > 0) & ~outlier[:, idx]
        if not exe.any():
            continue
        cnt = counts[:, idx]
        if levels[idx] == 1:
            ln = int(np.log2(idx))                         # lanes root at level 1
            lane[exe, idx] = ln
            prefix[exe, idx] = 0
            wl_ppe[exe, ln] += 1
            wl_ape[exe, ln] += cnt[exe]
            continue
        # Nodes with a backward-selected relay inherit its lane.
        pre = prefix[:, idx]
        has_pre = exe & (pre >= 0)
        if has_pre.any():
            s = np.nonzero(has_pre)[0]
            lane[s, idx] = lane[s, pre[s]]
        # Distance-1 nodes choose the least-loaded candidate lane (Fig. 5-5).
        free = exe & (pre < 0) & (dist[:, idx] == 1)
        if free.any():
            s = np.nonzero(free)[0]
            bm = pb[s, idx, 0]
            cands = cov_pre[idx]
            cand_bits = np.nonzero(cands >= 0)[0]
            lanes_c = np.full((len(s), len(cand_bits)), -1, dtype=np.int64)
            loads_c = np.full((len(s), len(cand_bits)), np.iinfo(np.int64).max,
                              dtype=np.int64)
            for j, b in enumerate(cand_bits):
                valid = (bm & (1 << b)) > 0
                cnode = int(cands[b])
                if cnode == 0:
                    cl = np.full(len(s), int(np.log2(idx & (1 << b))), dtype=np.int64)
                else:
                    cl = lane[s, cnode]
                valid &= cl >= 0
                lanes_c[valid, j] = cl[valid]
                loads_c[valid, j] = wl_ppe[s, cl][valid]
            pick = np.argmin(loads_c, axis=1)
            chosen_lane = lanes_c[np.arange(len(s)), pick]
            chosen_node = cov_pre[idx][cand_bits[pick]]
            good = chosen_lane >= 0
            lane[s[good], idx] = chosen_lane[good]
            prefix[s[good], idx] = chosen_node[good]
        # Update workloads for every executed instance of this node.
        upd = np.nonzero(exe & (lane[:, idx] >= 0))[0]
        ln = lane[upd, idx]
        np.add.at(wl_ppe, (upd, ln), 1)
        np.add.at(wl_ape, (upd, ln), cnt[upd])

    return ScoreboardInfo(t=t, n_rows=n_rows, counts=counts,
                          exec_counts=exec_counts, bridge=bridge,
                          distance=dist.astype(np.int64), prefix=prefix,
                          lane=lane, outlier=outlier,
                          wl_ppe=wl_ppe, wl_ape=wl_ape)


def static_scoreboard(all_rows: np.ndarray, t: int,
                      max_distance: int = MAX_DISTANCE) -> ScoreboardInfo:
    """Tensor-level static SI (Sec. 3.3): one scoreboard over all TransRows."""
    return dynamic_scoreboard(np.asarray(all_rows).reshape(1, -1), t,
                              max_distance)


def _chains(si: ScoreboardInfo) -> list[np.ndarray]:
    """Per-node global prefix chains node -> ... -> 0 from a static SI."""
    assert si.tiles == 1
    size = 1 << si.t
    prefix = si.prefix[0]
    chains: list[np.ndarray] = []
    for idx in range(size):
        chain = []
        cur = idx
        seen = 0
        while cur > 0 and prefix[cur] >= 0 and seen <= si.t:
            cur = int(prefix[cur])
            chain.append(cur)
            seen += 1
        chains.append(np.asarray(chain, dtype=np.int64))
    return chains


def static_tile_stats(si: ScoreboardInfo, rows: np.ndarray) -> dict:
    """Execute tiles against a *static* SI and count ops incl. SI misses.

    A node's prefix chain is fixed by the static SI. Inside one tile, we walk
    each present node's chain upward until we reach a node already computed
    in this tile (or the root); every hop is one PPE add, and chain nodes
    crossed become tile-local bridges (reusable). A prefix absent from the
    tile is the paper's **SI miss** (Sec. 3.3) — it costs the extra hops.

    Returns dict of per-tile op counts (ppe, ape, dense, bit) as int64 arrays.
    """
    rows = np.atleast_2d(np.asarray(rows))
    t = si.t
    size = 1 << t
    tiles, n_rows = rows.shape
    counts = _node_counts(rows, t)
    order = hasse.hamming_order(t)
    chains = _chains(si)
    levels = hasse.levels(t)
    static_exec = si.exec_counts[0] > 0

    computed = np.zeros((tiles, size), dtype=bool)
    ppe = np.zeros(tiles, dtype=np.int64)
    for idx in order:
        if idx == 0:
            continue
        here = counts[:, idx] > 0
        if not here.any():
            continue
        if si.outlier[0, idx] or not static_exec[idx]:
            # Static SI has no path for this node: direct accumulation.
            ppe[here] += int(levels[idx])
            computed[here, idx] = True
            continue
        chain = chains[idx]
        # hops[tile] = 1 + index of first chain node computed in this tile.
        hops = np.full(tiles, len(chain) + 1, dtype=np.int64)
        reached = np.zeros(tiles, dtype=bool)
        for j, cnode in enumerate(chain):
            hit = ~reached & (computed[:, cnode] | (cnode == 0))
            hops[hit] = j + 1
            reached |= hit
            # chain nodes crossed before the hit become tile-local bridges
        # Without a computed ancestor the chain ends at root (cnode 0 always
        # terminates chains of the static forest); anything else is direct.
        no_hit = here & ~reached
        if no_hit.any():
            ppe[no_hit] += int(levels[idx])
            computed[no_hit, idx] = True
        ok = here & reached
        ppe[ok] += hops[ok]
        computed[ok, idx] = True
        # mark crossed chain nodes computed (they were materialised)
        for j, cnode in enumerate(chain):
            crossed = ok & (hops > j + 1)
            if cnode != 0 and crossed.any():
                computed[crossed, cnode] = True

    nonzero_rows = n_rows - counts[:, 0]
    dense = np.full(tiles, n_rows * t, dtype=np.int64)
    bit = (counts.astype(np.int64) * levels[None, :]).sum(-1)
    return {"ppe": ppe, "ape": nonzero_rows.astype(np.int64),
            "dense": dense, "bit": bit}
