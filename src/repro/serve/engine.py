"""Continuous-batching serve engine over the paged KV pool.

:class:`ServeEngine` is the host scheduler the ROADMAP's serving story
needs around the quantized GEMM core: requests ``submit()`` at any time,
``step()`` admits arrivals into free batch slots, runs **one packed decode
step** over every active slot, and retires finished requests — freeing
their pages and re-opening their slots — without ever retracing. The
device only ever sees three programs:

  * a **bucketed batched prefill** (``Model.prefill_paged_batched``):
    pending same-wave prefills whose suffixes round up to the same
    power-of-two bucket run as ONE padded call, jit-keyed on
    ``(batch_bucket, suffix_bucket, n_prefix_pages)`` — the bucket set
    bounds prefill retraces regardless of prompt-length diversity
    (``bucket_prefill=False`` or an over-``CHUNK_THRESHOLD`` extent
    falls back to the per-request path below);
  * a per-request **suffix prefill** (``Model.prefill_paged``, batch 1),
    jit-keyed on ``(suffix_len, n_prefix_pages, write_from)``;
  * one fixed-shape **packed decode** (``Model.decode_step_paged``) over
    ``(n_slots, 1)`` tokens + the ``(n_slots, pages_per_slot)`` int32
    page table + per-slot ``steps`` — the same static-gather trick
    ``DevicePlan`` uses for forest schedules. Inactive slots point every
    table entry at the null page and carry step 0; their lanes compute
    garbage that is never read. ``paged_kernel=True`` routes its
    attention through the Pallas live-page kernel
    (:mod:`repro.kernels.paged_attention`), which walks only each
    slot's live pages instead of gathering the full ``pages_per_slot``
    extent.

Prompt prefixes are shared through the :class:`~repro.serve.paging.
PrefixTrie` at full-page granularity: a request whose prompt extends an
indexed prefix takes refcounts on those pages instead of re-prefilling
them. With an exact (fp/bf16) pool the shared range is *skipped at
compute time* (prefill sees only the suffix and gathers the shared K/V);
with an int8 pool (``kv_cache_bits=8``) the shared range is recomputed —
the dense reference attends over full-precision K/V during prefill, so
skipping compute would break bit-identity — but the shared pages are
still shared (per-token quantization is deterministic, the bytes match)
and only the non-shared tail is written.

Correctness bar, and the invariant the tests pin: every request's token
stream is **bit-identical** to running it alone through
``greedy_generate`` with the same ``max_len`` — the gathered cache view
has the same sequence extent, masked lanes contribute exact zeros, and
per-row math is batch-independent.

Weight updates hot-swap without draining: the engine's per-weight state
(params, page pool, allocator, prefix trie, slot arrays) lives in a
**generation cell**, and :meth:`ServeEngine.swap_params` stages a new
cell that is attached atomically at the next ``step()`` boundary — never
mid-step. In-flight requests finish on the generation that admitted them
(K/V bytes are a function of tokens *and* weights, so a request's cell —
pool, trie and all — stays alive until its last token); requests
admitted after the swap run on the new generation. The jitted device
programs are created once per engine and shared across generations, so a
swap whose params keep the same leaf avals (weight *values* changed, not
shapes — see ``repro.fleet.replan.align_device_plans`` for keeping
``DevicePlan`` pads stable) re-uses every existing trace:
``stats()["decode_jit_traces"]`` stays at 1 through the swap. See
docs/FLEET.md for the full protocol (staging, rollback, accounting).
All scheduling state is host-side; ``swap_params`` may be called from a
background replan thread (it only stages, under a lock).
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
import time
from collections import deque
from typing import Any, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat
from repro.models.attention import CHUNK_THRESHOLD
from repro.models.model import Model
from repro.serve.paging import PageAllocator, PrefixTrie
from repro.train.serve_step import _place_batch

__all__ = ["Request", "ServeEngine", "SwapMismatchError", "bucket"]


def bucket(n: int, cap: int) -> int:
    """Smallest power of two >= ``n``, clamped to ``cap``.

    The bucket set {1, 2, 4, ..., cap} is what bounds the engine's
    prefill jit specializations: suffix lengths, write widths and batch
    widths are all padded up to a bucket before reaching the device.
    """
    if n < 1:
        raise ValueError(f"bucket of non-positive {n}")
    b = 1
    while b < n:
        b *= 2
    return min(b, cap)


class SwapMismatchError(ValueError):
    """``swap_params`` was handed params the engine cannot serve: the
    pytree structure differs from the serving generation's. A hot swap
    replaces weight *values* (and, for planned backends, the DevicePlans
    riding inside the params); it never changes model architecture —
    that needs a new engine."""


@dataclasses.dataclass
class Request:
    """One generation request plus the engine's bookkeeping for it."""
    rid: int
    prompt: tuple
    max_new_tokens: int
    eos_id: int | None = None
    # -- engine state ------------------------------------------------------
    out: list = dataclasses.field(default_factory=list)
    page_ids: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    gen: int = 0               # weight generation that admitted (and owns) it
    length: int = 0            # K/V rows written: prompt, then +1 per step
    shared_pages: int = 0      # prompt pages taken from the prefix trie
    prefill_computed: int = 0  # prompt positions the prefill forward ran
    # -- timeline (perf_counter seconds / engine decode-step counts) ------
    t_submit: float = 0.0
    t_admit: float | None = None
    t_done: float | None = None
    submit_step: int = 0
    admit_step: int | None = None
    done_step: int | None = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def tokens(self) -> list:
        """Generated token ids (token 0 is the prefill argmax)."""
        return list(self.out)


@dataclasses.dataclass
class _Cell:
    """One weight generation's serving state.

    Everything whose bytes are a function of the weights lives here —
    params, page pool, allocator, prefix trie (it indexes K/V *bytes*),
    the packed slot arrays — so a hot swap is "append a new cell" and a
    request's generation is pinned by which cell admitted it. The jitted
    device programs stay on the engine: cells share them, which is what
    makes an aval-stable swap retrace-free.
    """
    gen: int
    params: Any
    pool: Any
    alloc: PageAllocator
    trie: PrefixTrie
    slots: list
    tokens: np.ndarray
    steps: np.ndarray
    table: np.ndarray
    tag: Any = None            # caller's label (checkpoint step, ...)

    @property
    def n_active(self) -> int:
        return sum(r is not None for r in self.slots)


class ServeEngine:
    """Paged-KV continuous-batching scheduler around one model.

    ``n_slots`` fixes the packed decode batch; ``max_len`` bounds any
    request's total (prompt + generated - 1) positions and must be a
    multiple of ``page_size``. ``n_pages`` defaults to
    ``n_slots * max_len / page_size + 1`` (page 0 is the null page), which
    guarantees admission and decode never run out of pages — trie-held
    pages beyond that working set are evicted LRU on demand. ``mesh=``
    runs both device programs under an ambient mesh with the packed slot
    arrays placed under the ``batch`` sharding rule (the same serve-cell
    topology as ``greedy_generate(mesh=)``). ``donate=False`` keeps the
    pool un-donated for callers that hold references across steps.

    ``paged_kernel=True`` decodes through the Pallas live-page attention
    kernel (cost grows with live pages, not ``max_len``);
    ``bucket_prefill=False`` reverts admission to per-request batch-1
    prefills. Both default to the pure-jnp oracle paths.

    Weights are swappable at runtime via :meth:`swap_params` — see the
    module docstring and docs/FLEET.md. ``params``/``pool``/``alloc``/
    ``trie``/``slots`` read through to the *current* generation's cell.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 n_pages: int | None = None, mesh=None,
                 donate: bool = True, paged_kernel: bool = False,
                 bucket_prefill: bool = True):
        reason = model.supports_paged()
        if reason is not None:
            raise NotImplementedError(f"paged serving: {reason}")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_len % page_size:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) so a slot's page table covers it exactly")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        self.n_pages = (n_slots * self.pages_per_slot + 1
                        if n_pages is None else n_pages)
        self.mesh = mesh
        self.paged_kernel = bool(paged_kernel)
        self.bucket_prefill = bool(bucket_prefill)
        # int8 pools share pages but must not skip prefill compute: the
        # dense reference attends over full-precision K/V while prefilling,
        # and a dequantized prefix would break bit-identity
        self.exact_pool = model.cfg.kv_cache_bits != 8
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.step_count = 0
        self._next_rid = 0
        # generation cells: [-1] is current (admission target), earlier
        # entries are draining their in-flight requests on old weights
        self._cells: list[_Cell] = [self._new_cell(0, params)]
        self._staged: tuple | None = None
        self._swap_lock = threading.Lock()
        self.swap_steps: list[int] = []
        # true trace counts: the wrapped bodies below run exactly once per
        # jit trace, so these count actual (re)traces — the observable the
        # hot-swap no-retrace guarantee is asserted on (trace *keys* in
        # _trace_keys count requested specializations, not compilations)
        self.jit_traces = {"prefill": 0, "prefill_batched": 0, "decode": 0}
        traces = self.jit_traces

        def _prefill_fn(params, tokens, pool, *, prefix_page_ids,
                        write_page_ids, write_offs, write_from=0):
            traces["prefill"] += 1
            return model.prefill_paged(
                params, tokens, pool, prefix_page_ids=prefix_page_ids,
                write_page_ids=write_page_ids, write_offs=write_offs,
                write_from=write_from)

        def _prefill_batched_fn(params, tokens, pool, *, prefix_page_ids,
                                prefix_lens, suffix_lens, write_page_ids,
                                write_offs, write_pos):
            traces["prefill_batched"] += 1
            return model.prefill_paged_batched(
                params, tokens, pool, prefix_page_ids=prefix_page_ids,
                prefix_lens=prefix_lens, suffix_lens=suffix_lens,
                write_page_ids=write_page_ids, write_offs=write_offs,
                write_pos=write_pos)

        def _decode_fn(params, pool, tokens, page_indices, steps,
                       kernel=None):
            traces["decode"] += 1
            return model.decode_step_paged(params, pool, tokens,
                                           page_indices, steps,
                                           kernel=kernel)

        self._prefill = jax.jit(_prefill_fn,
                                static_argnames=("write_from",),
                                donate_argnums=(2,) if donate else ())
        self._prefill_batched = jax.jit(_prefill_batched_fn,
                                        donate_argnums=(2,) if donate
                                        else ())
        self._decode = jax.jit(_decode_fn,
                               static_argnames=("kernel",),
                               donate_argnums=(1,) if donate else ())
        # distinct jit specializations actually requested, per program —
        # the observable the bucketing win is measured by
        self._trace_keys: dict[str, set] = {"prefill": set(),
                                            "decode": set()}
        self.counters = {"admitted": 0, "completed": 0, "decode_steps": 0,
                         "decode_tokens": 0, "prefix_hits": 0,
                         "pages_shared": 0, "prefill_computed": 0,
                         "prefill_skipped": 0, "prefill_written": 0,
                         "prefill_calls": 0, "prefill_batched_calls": 0,
                         "prefill_batched_rows": 0, "prefill_pad_rows": 0,
                         "bucket_hits": 0, "swaps": 0, "swaps_staged": 0,
                         "swaps_superseded": 0, "swap_shape_drift": 0,
                         "generations_retired": 0}

    def _new_cell(self, gen: int, params, tag=None) -> _Cell:
        return _Cell(
            gen=gen, params=params,
            pool=self.model.init_page_pool(self.n_pages, self.page_size),
            alloc=PageAllocator(self.n_pages),
            trie=PrefixTrie(self.page_size),
            slots=[None] * self.n_slots,
            tokens=np.zeros((self.n_slots, 1), np.int32),
            steps=np.zeros((self.n_slots,), np.int32),
            table=np.zeros((self.n_slots, self.pages_per_slot), np.int32),
            tag=tag)

    # -- current-generation views (admission target; old cells drain) -----
    @property
    def cell(self) -> _Cell:
        return self._cells[-1]

    @property
    def generation(self) -> int:
        return self.cell.gen

    @property
    def params(self):
        return self.cell.params

    @property
    def pool(self):
        return self.cell.pool

    @property
    def alloc(self) -> PageAllocator:
        return self.cell.alloc

    @property
    def trie(self) -> PrefixTrie:
        return self.cell.trie

    @property
    def slots(self) -> list:
        return self.cell.slots

    # -- hot swap ----------------------------------------------------------
    def swap_params(self, params, *, tag=None) -> int:
        """Stage a weight-generation swap; returns the new generation id.

        Applied atomically at the start of the next :meth:`step` — never
        mid-step. Non-draining: requests already in flight keep decoding
        on the generation that admitted them (its cell — params, pool,
        trie — stays alive until they finish); requests admitted after
        the swap run on the new weights. Thread-safe: this only *stages*
        (a background replan worker may call it); the scheduling thread
        applies. Staging again before the next step supersedes the
        earlier staged params (newest weights win — counted in
        ``swaps_superseded``).

        ``params`` must have the serving generation's pytree structure
        (else :class:`SwapMismatchError`; the caller's rollback is to
        simply not swap). Leaf-shape drift is allowed — it happens when a
        planned backend's ``DevicePlan`` direct width grows past the pad
        (see ``repro.fleet.replan.align_device_plans``) — but costs one
        retrace and is surfaced in ``swap_shape_drift``.
        """
        cur = self.cell.params
        if (jax.tree_util.tree_structure(params)
                != jax.tree_util.tree_structure(cur)):
            raise SwapMismatchError(
                "swap_params: new params pytree structure differs from "
                "the serving generation's — a hot swap replaces weight "
                "values, not model architecture (build a new engine for "
                "that)")
        # trust boundary: a replan worker's DevicePlans are verified at
        # staging time — a malformed plan never waits in _staged where
        # the scheduling thread would attach it mid-serve
        from repro.analysis.planlint import gate_params
        gate_params(params, where="swap-staging")
        drift = sum(
            getattr(a, "shape", None) != getattr(b, "shape", None)
            or getattr(a, "dtype", None) != getattr(b, "dtype", None)
            for a, b in zip(jax.tree_util.tree_leaves(params),
                            jax.tree_util.tree_leaves(cur)))
        with self._swap_lock:
            superseded = self._staged is not None
            self._staged = (params, tag, drift)
        self.counters["swaps_staged"] += 1
        if superseded:
            self.counters["swaps_superseded"] += 1
        return self.cell.gen + 1

    def _apply_staged(self) -> None:
        """Attach a staged generation (scheduling thread, step boundary)."""
        with self._swap_lock:
            staged, self._staged = self._staged, None
        if staged is None:
            return
        params, tag, drift = staged
        self._cells.append(self._new_cell(self.cell.gen + 1, params,
                                          tag=tag))
        self.counters["swaps"] += 1
        self.counters["swap_shape_drift"] += drift
        self.swap_steps.append(self.step_count)

    def _retire_cells(self) -> None:
        """Drop old generations whose last in-flight request finished
        (frees their pool/trie); the current cell always stays."""
        for cell in [c for c in self._cells[:-1] if c.n_active == 0]:
            self._cells.remove(cell)
            self.counters["generations_retired"] += 1

    def _cell_of(self, gen: int) -> _Cell:
        for cell in self._cells:
            if cell.gen == gen:
                return cell
        raise KeyError(f"generation {gen} already retired")

    # -- submission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: int | None = None) -> int:
        """Queue a request; returns its id. Admission happens in step()."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        # token 0 comes from prefill; decode i writes K/V position
        # len(prompt) + i - 1, so the last write lands at
        # L + max_new_tokens - 2 and must stay under max_len
        if len(prompt) + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) - 1 exceeds max_len ({self.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                      t_submit=time.perf_counter(),
                      submit_step=self.step_count)
        self.queue.append(req)
        return rid

    # -- scheduling --------------------------------------------------------
    def _mesh_ctx(self):
        return (jax_compat.set_mesh(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    def _alloc_page(self, cell: _Cell) -> int | None:
        """One page, evicting trie-only pages (LRU) under pressure."""
        pid = cell.alloc.alloc()
        if pid is None and cell.trie.evict(cell.alloc, 1):
            pid = cell.alloc.alloc()
        return pid

    def _note_trace(self, kind: str, key: tuple) -> bool:
        """Record a jit-specialization key; True when already traced."""
        keys = self._trace_keys[kind]
        if key in keys:
            return True
        keys.add(key)
        return False

    def _reserve(self, req: Request) -> dict | None:
        """Match/pin/allocate ``req``'s prompt pages; None = no pages yet.

        Reserved pages carry the request's refcount, so later same-wave
        reservations can evict around them but never reclaim them. The
        prompt is indexed into the trie immediately — a request arriving
        later in the same wave already shares these pages (the run
        partitioning in :meth:`_admit` keeps its prefill *after* the
        batch that writes them). Always against the current cell: only
        the current generation admits.
        """
        cell = self.cell
        L, ps = len(req.prompt), self.page_size
        n_prompt_pages = -(-L // ps)
        # cap the match so the suffix keeps >= 1 token: the last prompt
        # position must run through prefill to produce the step-0 logits,
        # and decode must never append to a page another request holds
        shared = cell.trie.match(req.prompt, max_pages=(L - 1) // ps)
        for pid in shared:            # pin before eviction can see them
            cell.alloc.incref(pid)
        need = n_prompt_pages - len(shared)
        if cell.alloc.free_count < need:
            cell.trie.evict(cell.alloc, need - cell.alloc.free_count)
        if cell.alloc.free_count < need:
            for pid in shared:
                cell.alloc.decref(pid)
            return None
        page_ids = list(shared) + [cell.alloc.alloc() for _ in range(need)]
        cell.trie.insert(req.prompt, page_ids, cell.alloc)
        return {"req": req, "page_ids": page_ids, "shared": len(shared)}

    def _seat(self, res: dict, tok: int) -> None:
        """Post-prefill bookkeeping: record token, counters, slot/table."""
        cell = self.cell
        req = res["req"]
        L, ps = len(req.prompt), self.page_size
        shared = res["shared"]
        shared_len = shared * ps
        start = shared_len if self.exact_pool else 0
        req.gen = cell.gen
        req.out.append(tok)
        req.length = L
        req.page_ids = res["page_ids"]
        req.shared_pages = shared
        req.prefill_computed = L - start
        req.t_admit = time.perf_counter()
        req.admit_step = self.step_count
        self.counters["admitted"] += 1
        self.counters["prefix_hits"] += bool(shared)
        self.counters["pages_shared"] += shared
        self.counters["prefill_computed"] += L - start
        self.counters["prefill_skipped"] += shared_len
        self.counters["prefill_written"] += L - shared_len
        if len(req.out) >= req.max_new_tokens or tok == req.eos_id:
            self._finish(req)
        else:
            slot = cell.slots.index(None)
            req.slot = slot
            cell.slots[slot] = req.rid
            self.active[req.rid] = req
            cell.tokens[slot, 0] = tok
            cell.steps[slot] = req.length
            cell.table[slot, :len(req.page_ids)] = req.page_ids

    def _prefill_one(self, res: dict) -> None:
        """Per-request batch-1 prefill (the original, always-exact path)."""
        cell = self.cell
        req, page_ids = res["req"], res["page_ids"]
        L, ps = len(req.prompt), self.page_size
        shared_len = res["shared"] * ps
        if self.exact_pool:
            start, write_from = shared_len, 0   # skip shared compute
        else:
            start, write_from = 0, shared_len   # recompute, share bytes
        suffix = np.asarray([req.prompt[start:]], np.int32)
        prefix = np.asarray(page_ids[:start // ps], np.int32)
        wp = np.asarray([page_ids[p // ps] for p in range(shared_len, L)],
                        np.int32)
        wo = np.asarray([p % ps for p in range(shared_len, L)], np.int32)
        self.counters["prefill_calls"] += 1
        self._note_trace("prefill", ("one", L - start, start // ps,
                                     write_from))
        with self._mesh_ctx():
            logits, cell.pool = self._prefill(
                cell.params, jnp.asarray(suffix), cell.pool,
                prefix_page_ids=jnp.asarray(prefix),
                write_page_ids=jnp.asarray(wp), write_offs=jnp.asarray(wo),
                write_from=write_from)
            tok = int(np.asarray(
                jnp.argmax(logits[:, -1], -1).astype(jnp.int32))[0])
        self._seat(res, tok)

    def _bucket_key(self, res: dict) -> tuple:
        """(suffix_bucket, n_prefix_pages) jit grouping key for a
        reservation. The prefix page count stays EXACT (not bucketed):
        padding it would interleave zero lanes mid-extent and shift the
        suffix lanes' reduction association — trailing suffix/batch
        padding is the bit-exact kind (see attention.py)."""
        L, ps = len(res["req"].prompt), self.page_size
        start = res["shared"] * ps if self.exact_pool else 0
        return bucket(L - start, self.max_len), start // ps

    def _prefill_group(self, group: list[dict]) -> None:
        """One padded batched prefill over same-bucket reservations."""
        cell = self.cell
        ps = self.page_size
        lb, n_pre = self._bucket_key(group[0])
        if not self.bucket_prefill or n_pre * ps + lb > CHUNK_THRESHOLD:
            for res in group:
                self._prefill_one(res)
            return
        nb = bucket(len(group), self.n_slots)
        tokens = np.zeros((nb, lb), np.int32)
        prefix = np.zeros((nb, n_pre), np.int32)
        plens = np.zeros((nb,), np.int32)
        slens = np.ones((nb,), np.int32)    # dead rows read garbage row 0
        wp = np.zeros((nb, lb), np.int32)   # dead lanes hit the null page
        wo = np.zeros((nb, lb), np.int32)
        wpos = np.zeros((nb, lb), np.int32)
        for r, res in enumerate(group):
            req, page_ids = res["req"], res["page_ids"]
            L = len(req.prompt)
            shared_len = res["shared"] * ps
            start = shared_len if self.exact_pool else 0
            ls = L - start
            tokens[r, :ls] = req.prompt[start:]
            plens[r] = start
            prefix[r, :start // ps] = page_ids[:start // ps]
            slens[r] = ls
            for i, p in enumerate(range(shared_len, L)):
                wp[r, i] = page_ids[p // ps]
                wo[r, i] = p % ps
                wpos[r, i] = p - start
        self.counters["prefill_batched_calls"] += 1
        self.counters["prefill_batched_rows"] += len(group)
        self.counters["prefill_pad_rows"] += nb - len(group)
        if self._note_trace("prefill", ("batched", nb, lb, n_pre)):
            self.counters["bucket_hits"] += 1
        with self._mesh_ctx():
            logits, cell.pool = self._prefill_batched(
                cell.params, jnp.asarray(tokens), cell.pool,
                prefix_page_ids=jnp.asarray(prefix),
                prefix_lens=jnp.asarray(plens),
                suffix_lens=jnp.asarray(slens),
                write_page_ids=jnp.asarray(wp), write_offs=jnp.asarray(wo),
                write_pos=jnp.asarray(wpos))
            toks = np.asarray(jnp.argmax(logits[:, -1], -1)
                              .astype(jnp.int32))
        for r, res in enumerate(group):
            self._seat(res, int(toks[r]))

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            free = self.slots.count(None)
            wave: list[dict] = []
            while self.queue and len(wave) < free:
                res = self._reserve(self.queue[0])
                if res is None:
                    break             # page pressure: retry next step
                self.queue.popleft()
                wave.append(res)
            if not wave:
                break
            # partition into runs: a reservation whose trie-shared pages
            # are WRITTEN by an earlier same-wave reservation must prefill
            # after the batch that fills them — runs flush in order, and
            # within a run no request reads another's pending writes
            runs: list[list[dict]] = []
            cur: list[dict] = []
            pending_writes: set[int] = set()
            for res in wave:
                shared_ids = set(res["page_ids"][:res["shared"]])
                if cur and (shared_ids & pending_writes):
                    runs.append(cur)
                    cur, pending_writes = [], set()
                cur.append(res)
                pending_writes |= set(res["page_ids"][res["shared"]:])
            if cur:
                runs.append(cur)
            for run in runs:
                groups: dict[tuple, list[dict]] = {}
                for res in run:
                    groups.setdefault(self._bucket_key(res),
                                      []).append(res)
                for group in groups.values():
                    self._prefill_group(group)

    def _finish(self, req: Request) -> None:
        cell = self._cell_of(req.gen)
        if req.slot is not None:
            cell.slots[req.slot] = None
            del self.active[req.rid]
            cell.tokens[req.slot, 0] = 0
            cell.steps[req.slot] = 0
            cell.table[req.slot, :] = 0
            req.slot = None
        for pid in req.page_ids:
            cell.alloc.decref(pid)    # trie-held pages survive (refcount)
        req.t_done = time.perf_counter()
        req.done_step = self.step_count
        self.counters["completed"] += 1
        self.finished.append(req)

    def _decode_cell(self, cell: _Cell,
                     packed: list[tuple[int, Request]]) -> None:
        """One packed decode over ``cell``'s active slots."""
        self.counters["decode_steps"] += 1
        for s, req in packed:
            # this step writes K/V position req.length — grow the
            # request's table when it crosses a page boundary; the
            # persistent host arrays only take the per-slot deltas
            # (_seat/_finish maintain the rest)
            if req.length // self.page_size >= len(req.page_ids):
                pid = self._alloc_page(cell)
                if pid is None:
                    raise RuntimeError(
                        f"page pool exhausted ({cell.alloc!r}) — "
                        f"size n_pages for the slot working set")
                req.page_ids.append(pid)
                cell.table[s, len(req.page_ids) - 1] = pid
            cell.tokens[s, 0] = req.out[-1]
            cell.steps[s] = req.length
        batch = {"tokens": cell.tokens, "table": cell.table,
                 "steps": cell.steps}
        self._note_trace("decode", ("decode", self.paged_kernel))
        with self._mesh_ctx():
            if self.mesh is not None:
                batch = _place_batch(batch, self.mesh)
            logits, cell.pool = self._decode(
                cell.params, cell.pool, jnp.asarray(batch["tokens"]),
                jnp.asarray(batch["table"]),
                jnp.asarray(batch["steps"]),
                kernel=self.paged_kernel)
            toks = np.asarray(
                jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
        done = []
        for s, req in packed:
            tok = int(toks[s])
            req.out.append(tok)
            req.length += 1
            self.counters["decode_tokens"] += 1
            if (len(req.out) >= req.max_new_tokens
                    or tok == req.eos_id):
                done.append(req)
        for req in done:
            self._finish(req)

    def step(self) -> list[Request]:
        """Attach a staged swap, admit arrivals, run one packed decode
        step per live generation, retire finished requests and drained
        generations.

        Returns the requests that finished during this call (their
        ``tokens`` are final). A request admitted this step decodes this
        step: its prefill token feeds the packed decode exactly like
        ``greedy_generate``'s first loop iteration. A staged swap is
        applied *before* admission, so requests taken off the queue this
        step already run on the new weights, while earlier generations
        keep decoding their in-flight requests in the same call —
        swapping never skips anyone's decode step.
        """
        n_done = len(self.finished)
        self._apply_staged()
        self._admit()
        packed_by_cell = [
            (cell, [(s, self.active[rid])
                    for s, rid in enumerate(cell.slots) if rid is not None])
            for cell in list(self._cells)]
        if any(packed for _, packed in packed_by_cell):
            self.step_count += 1
            for cell, packed in packed_by_cell:
                if packed:
                    self._decode_cell(cell, packed)
        self._retire_cells()
        return self.finished[n_done:]

    def run(self, max_steps: int = 100_000) -> list[Request]:
        """Drive step() until every submitted request finished."""
        n_done = len(self.finished)
        steps = 0
        while self.queue or self.active:
            if steps >= max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps")
            steps += 1
            before = (len(self.queue), len(self.active),
                      len(self.finished))
            self.step()
            if not self.active and before == (len(self.queue),
                                              len(self.active),
                                              len(self.finished)):
                raise RuntimeError(
                    f"scheduler stalled: {len(self.queue)} queued "
                    f"request(s) cannot be admitted "
                    f"(pages: {self.alloc!r}, trie: {self.trie!r})")
        return self.finished[n_done:]

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        active_by_gen: dict[int, int] = {}
        for r in self.active.values():
            active_by_gen[r.gen] = active_by_gen.get(r.gen, 0) + 1
        cur = self.cell.gen
        return {**self.counters, "queued": len(self.queue),
                "active": len(self.active),
                "finished": len(self.finished),
                "prefill_traces": len(self._trace_keys["prefill"]),
                "decode_traces": len(self._trace_keys["decode"]),
                "prefill_jit_traces": (self.jit_traces["prefill"]
                                       + self.jit_traces["prefill_batched"]),
                "decode_jit_traces": self.jit_traces["decode"],
                "generation": cur,
                "draining_generations": len(self._cells) - 1,
                "active_by_gen": active_by_gen,
                "in_flight_prev_gen": sum(n for g, n in active_by_gen.items()
                                          if g != cur),
                "pages": self.alloc.stats(), "trie": self.trie.stats()}

    def report(self) -> dict:
        """Latency/throughput summary over the finished requests."""
        reqs = self.finished
        per = [{"rid": r.rid, "prompt_len": len(r.prompt),
                "n_tokens": len(r.out),
                "gen": r.gen,
                "shared_pages": r.shared_pages,
                "prefill_computed": r.prefill_computed,
                "ttft_s": (r.t_admit or r.t_submit) - r.t_submit,
                "latency_s": (r.t_done - r.t_submit) if r.done else None}
               for r in reqs]
        total_tokens = sum(len(r.out) for r in reqs)
        t0 = min((r.t_submit for r in reqs), default=0.0)
        t1 = max((r.t_done for r in reqs if r.done), default=t0)
        wall = max(t1 - t0, 1e-9)
        return {"requests": per, "n_requests": len(reqs),
                "total_tokens": total_tokens, "wall_s": wall,
                "tokens_per_s": total_tokens / wall,
                "counters": self.stats()}

    def __repr__(self) -> str:
        return (f"ServeEngine(gen={self.cell.gen} "
                f"slots={self.cell.n_active}/{self.n_slots} "
                f"queued={len(self.queue)} "
                f"finished={len(self.finished)} steps={self.step_count})")
