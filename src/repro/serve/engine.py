"""Continuous-batching serve engine over the paged KV pool.

:class:`ServeEngine` is the host scheduler the ROADMAP's serving story
needs around the quantized GEMM core: requests ``submit()`` at any time,
``step()`` admits arrivals into free batch slots, runs **one packed decode
step** over every active slot, and retires finished requests — freeing
their pages and re-opening their slots — without ever retracing. The
device only ever sees two programs:

  * a per-request **suffix prefill** (``Model.prefill_paged``, batch 1),
    jit-keyed on ``(suffix_len, n_prefix_pages, write_from)``;
  * one fixed-shape **packed decode** (``Model.decode_step_paged``) over
    ``(n_slots, 1)`` tokens + the ``(n_slots, pages_per_slot)`` int32
    page table + per-slot ``steps`` — the same static-gather trick
    ``DevicePlan`` uses for forest schedules. Inactive slots point every
    table entry at the null page and carry step 0; their lanes compute
    garbage that is never read.

Prompt prefixes are shared through the :class:`~repro.serve.paging.
PrefixTrie` at full-page granularity: a request whose prompt extends an
indexed prefix takes refcounts on those pages instead of re-prefilling
them. With an exact (fp/bf16) pool the shared range is *skipped at
compute time* (prefill sees only the suffix and gathers the shared K/V);
with an int8 pool (``kv_cache_bits=8``) the shared range is recomputed —
the dense reference attends over full-precision K/V during prefill, so
skipping compute would break bit-identity — but the shared pages are
still shared (per-token quantization is deterministic, the bytes match)
and only the non-shared tail is written.

Correctness bar, and the invariant the tests pin: every request's token
stream is **bit-identical** to running it alone through
``greedy_generate`` with the same ``max_len`` — the gathered cache view
has the same sequence extent, masked lanes contribute exact zeros, and
per-row math is batch-independent.

The engine owns one page pool per (model, params): weight updates need a
fresh engine (the trie indexes K/V bytes, which are a function of both).
All scheduling state is host-side and single-threaded.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import jax_compat
from repro.models.model import Model
from repro.serve.paging import PageAllocator, PrefixTrie
from repro.train.serve_step import _place_batch

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    """One generation request plus the engine's bookkeeping for it."""
    rid: int
    prompt: tuple
    max_new_tokens: int
    eos_id: int | None = None
    # -- engine state ------------------------------------------------------
    out: list = dataclasses.field(default_factory=list)
    page_ids: list = dataclasses.field(default_factory=list)
    slot: int | None = None
    length: int = 0            # K/V rows written: prompt, then +1 per step
    shared_pages: int = 0      # prompt pages taken from the prefix trie
    prefill_computed: int = 0  # prompt positions the prefill forward ran
    # -- timeline (perf_counter seconds / engine decode-step counts) ------
    t_submit: float = 0.0
    t_admit: float | None = None
    t_done: float | None = None
    submit_step: int = 0
    admit_step: int | None = None
    done_step: int | None = None

    @property
    def done(self) -> bool:
        return self.t_done is not None

    @property
    def tokens(self) -> list:
        """Generated token ids (token 0 is the prefill argmax)."""
        return list(self.out)


class ServeEngine:
    """Paged-KV continuous-batching scheduler around one (model, params).

    ``n_slots`` fixes the packed decode batch; ``max_len`` bounds any
    request's total (prompt + generated - 1) positions and must be a
    multiple of ``page_size``. ``n_pages`` defaults to
    ``n_slots * max_len / page_size + 1`` (page 0 is the null page), which
    guarantees admission and decode never run out of pages — trie-held
    pages beyond that working set are evicted LRU on demand. ``mesh=``
    runs both device programs under an ambient mesh with the packed slot
    arrays placed under the ``batch`` sharding rule (the same serve-cell
    topology as ``greedy_generate(mesh=)``). ``donate=False`` keeps the
    pool un-donated for callers that hold references across steps.
    """

    def __init__(self, model: Model, params, *, n_slots: int = 4,
                 max_len: int = 256, page_size: int = 16,
                 n_pages: int | None = None, mesh=None,
                 donate: bool = True):
        reason = model.supports_paged()
        if reason is not None:
            raise NotImplementedError(f"paged serving: {reason}")
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        if max_len % page_size:
            raise ValueError(
                f"max_len ({max_len}) must be a multiple of page_size "
                f"({page_size}) so a slot's page table covers it exactly")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = max_len // page_size
        self.n_pages = (n_slots * self.pages_per_slot + 1
                        if n_pages is None else n_pages)
        self.mesh = mesh
        # int8 pools share pages but must not skip prefill compute: the
        # dense reference attends over full-precision K/V while prefilling,
        # and a dequantized prefix would break bit-identity
        self.exact_pool = model.cfg.kv_cache_bits != 8
        self.pool = model.init_page_pool(self.n_pages, page_size)
        self.alloc = PageAllocator(self.n_pages)
        self.trie = PrefixTrie(page_size)
        self.slots: list[int | None] = [None] * n_slots
        self.queue: deque[Request] = deque()
        self.active: dict[int, Request] = {}
        self.finished: list[Request] = []
        self.step_count = 0
        self._next_rid = 0
        self._prefill = jax.jit(model.prefill_paged,
                                static_argnames=("write_from",),
                                donate_argnums=(2,) if donate else ())
        self._decode = jax.jit(model.decode_step_paged,
                               donate_argnums=(1,) if donate else ())
        self.counters = {"admitted": 0, "completed": 0, "decode_steps": 0,
                         "decode_tokens": 0, "prefix_hits": 0,
                         "pages_shared": 0, "prefill_computed": 0,
                         "prefill_skipped": 0, "prefill_written": 0}

    # -- submission --------------------------------------------------------
    def submit(self, prompt: Sequence[int], max_new_tokens: int,
               eos_id: int | None = None) -> int:
        """Queue a request; returns its id. Admission happens in step()."""
        prompt = tuple(int(t) for t in prompt)
        if not prompt:
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens}")
        # token 0 comes from prefill; decode i writes K/V position
        # len(prompt) + i - 1, so the last write lands at
        # L + max_new_tokens - 2 and must stay under max_len
        if len(prompt) + max_new_tokens - 1 > self.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) - 1 exceeds max_len ({self.max_len})")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt,
                      max_new_tokens=int(max_new_tokens), eos_id=eos_id,
                      t_submit=time.perf_counter(),
                      submit_step=self.step_count)
        self.queue.append(req)
        return rid

    # -- scheduling --------------------------------------------------------
    def _mesh_ctx(self):
        return (jax_compat.set_mesh(self.mesh) if self.mesh is not None
                else contextlib.nullcontext())

    def _alloc_page(self) -> int | None:
        """One page, evicting trie-only pages (LRU) under pressure."""
        pid = self.alloc.alloc()
        if pid is None and self.trie.evict(self.alloc, 1):
            pid = self.alloc.alloc()
        return pid

    def _admit_one(self, req: Request, slot: int) -> bool:
        """Prefill ``req`` into pages and seat it; False = no pages yet."""
        L, ps = len(req.prompt), self.page_size
        n_prompt_pages = -(-L // ps)
        # cap the match so the suffix keeps >= 1 token: the last prompt
        # position must run through prefill to produce the step-0 logits,
        # and decode must never append to a page another request holds
        shared = self.trie.match(req.prompt, max_pages=(L - 1) // ps)
        for pid in shared:            # pin before eviction can see them
            self.alloc.incref(pid)
        need = n_prompt_pages - len(shared)
        if self.alloc.free_count < need:
            self.trie.evict(self.alloc, need - self.alloc.free_count)
        if self.alloc.free_count < need:
            for pid in shared:
                self.alloc.decref(pid)
            return False
        page_ids = list(shared) + [self.alloc.alloc() for _ in range(need)]
        shared_len = len(shared) * ps
        if self.exact_pool:
            start, write_from = shared_len, 0   # skip shared compute
        else:
            start, write_from = 0, shared_len   # recompute, share bytes
        suffix = np.asarray([req.prompt[start:]], np.int32)
        prefix = np.asarray(page_ids[:start // ps], np.int32)
        wp = np.asarray([page_ids[p // ps] for p in range(shared_len, L)],
                        np.int32)
        wo = np.asarray([p % ps for p in range(shared_len, L)], np.int32)
        with self._mesh_ctx():
            logits, self.pool = self._prefill(
                self.params, jnp.asarray(suffix), self.pool,
                prefix_page_ids=jnp.asarray(prefix),
                write_page_ids=jnp.asarray(wp), write_offs=jnp.asarray(wo),
                write_from=write_from)
            tok = int(np.asarray(
                jnp.argmax(logits[:, -1], -1).astype(jnp.int32))[0])
        req.out.append(tok)
        req.length = L
        req.page_ids = page_ids
        req.shared_pages = len(shared)
        req.prefill_computed = L - start
        req.t_admit = time.perf_counter()
        req.admit_step = self.step_count
        self.counters["admitted"] += 1
        self.counters["prefix_hits"] += bool(shared)
        self.counters["pages_shared"] += len(shared)
        self.counters["prefill_computed"] += L - start
        self.counters["prefill_skipped"] += shared_len
        self.counters["prefill_written"] += L - shared_len
        # index the freshly filled prompt pages immediately, so a request
        # arriving next step (or later this step) can already share them
        self.trie.insert(req.prompt, page_ids, self.alloc)
        if len(req.out) >= req.max_new_tokens or tok == req.eos_id:
            self._finish(req)
        else:
            req.slot = slot
            self.slots[slot] = req.rid
            self.active[req.rid] = req
        return True

    def _admit(self) -> None:
        while self.queue and None in self.slots:
            if not self._admit_one(self.queue[0],
                                   self.slots.index(None)):
                break                 # page pressure: retry next step
            self.queue.popleft()

    def _finish(self, req: Request) -> None:
        if req.slot is not None:
            self.slots[req.slot] = None
            del self.active[req.rid]
            req.slot = None
        for pid in req.page_ids:
            self.alloc.decref(pid)    # trie-held pages survive (refcount)
        req.t_done = time.perf_counter()
        req.done_step = self.step_count
        self.counters["completed"] += 1
        self.finished.append(req)

    def step(self) -> list[Request]:
        """Admit arrivals, run one packed decode step, retire finished.

        Returns the requests that finished during this call (their
        ``tokens`` are final). A request admitted this step decodes this
        step: its prefill token feeds the packed decode exactly like
        ``greedy_generate``'s first loop iteration.
        """
        n_done = len(self.finished)
        self._admit()
        packed = [(s, self.active[rid])
                  for s, rid in enumerate(self.slots) if rid is not None]
        if packed:
            self.step_count += 1
            self.counters["decode_steps"] += 1
            tokens = np.zeros((self.n_slots, 1), np.int32)
            steps = np.zeros((self.n_slots,), np.int32)
            table = np.zeros((self.n_slots, self.pages_per_slot), np.int32)
            for s, req in packed:
                # this step writes K/V position req.length — grow the
                # request's table when it crosses a page boundary
                if req.length // self.page_size >= len(req.page_ids):
                    pid = self._alloc_page()
                    if pid is None:
                        raise RuntimeError(
                            f"page pool exhausted ({self.alloc!r}) — "
                            f"size n_pages for the slot working set")
                    req.page_ids.append(pid)
                tokens[s, 0] = req.out[-1]
                steps[s] = req.length
                table[s, :len(req.page_ids)] = req.page_ids
            batch = {"tokens": tokens, "table": table, "steps": steps}
            with self._mesh_ctx():
                if self.mesh is not None:
                    batch = _place_batch(batch, self.mesh)
                logits, self.pool = self._decode(
                    self.params, self.pool, jnp.asarray(batch["tokens"]),
                    jnp.asarray(batch["table"]),
                    jnp.asarray(batch["steps"]))
                toks = np.asarray(
                    jnp.argmax(logits[:, -1], -1).astype(jnp.int32))
            done = []
            for s, req in packed:
                tok = int(toks[s])
                req.out.append(tok)
                req.length += 1
                self.counters["decode_tokens"] += 1
                if (len(req.out) >= req.max_new_tokens
                        or tok == req.eos_id):
                    done.append(req)
            for req in done:
                self._finish(req)
        return self.finished[n_done:]

    def run(self, max_steps: int = 100_000) -> list[Request]:
        """Drive step() until every submitted request finished."""
        n_done = len(self.finished)
        steps = 0
        while self.queue or self.active:
            if steps >= max_steps:
                raise RuntimeError(f"run() exceeded {max_steps} steps")
            steps += 1
            before = (len(self.queue), len(self.active),
                      len(self.finished))
            self.step()
            if not self.active and before == (len(self.queue),
                                              len(self.active),
                                              len(self.finished)):
                raise RuntimeError(
                    f"scheduler stalled: {len(self.queue)} queued "
                    f"request(s) cannot be admitted "
                    f"(pages: {self.alloc!r}, trie: {self.trie!r})")
        return self.finished[n_done:]

    # -- introspection -----------------------------------------------------
    def stats(self) -> dict:
        return {**self.counters, "queued": len(self.queue),
                "active": len(self.active),
                "finished": len(self.finished),
                "pages": self.alloc.stats(), "trie": self.trie.stats()}

    def report(self) -> dict:
        """Latency/throughput summary over the finished requests."""
        reqs = self.finished
        per = [{"rid": r.rid, "prompt_len": len(r.prompt),
                "n_tokens": len(r.out),
                "shared_pages": r.shared_pages,
                "prefill_computed": r.prefill_computed,
                "ttft_s": (r.t_admit or r.t_submit) - r.t_submit,
                "latency_s": (r.t_done - r.t_submit) if r.done else None}
               for r in reqs]
        total_tokens = sum(len(r.out) for r in reqs)
        t0 = min((r.t_submit for r in reqs), default=0.0)
        t1 = max((r.t_done for r in reqs if r.done), default=t0)
        wall = max(t1 - t0, 1e-9)
        return {"requests": per, "n_requests": len(reqs),
                "total_tokens": total_tokens, "wall_s": wall,
                "tokens_per_s": total_tokens / wall,
                "counters": self.stats()}

    def __repr__(self) -> str:
        return (f"ServeEngine(slots={sum(r is not None for r in self.slots)}"
                f"/{self.n_slots} queued={len(self.queue)} "
                f"finished={len(self.finished)} steps={self.step_count})")
