"""Continuous-batching serving: paged KV pool + prefix trie + scheduler.

See docs/SERVING.md for the page-table layout, scheduler semantics and
eviction rules. Public surface:

  * :class:`~repro.serve.engine.ServeEngine` / ``Request`` — the
    submit/step scheduler over a packed, zero-retrace decode.
  * :class:`~repro.serve.paging.PageAllocator` /
    :class:`~repro.serve.paging.PrefixTrie` — the host-side page
    bookkeeping (refcounted free list; prompt-prefix page sharing).
"""
from repro.serve.engine import Request, ServeEngine, bucket
from repro.serve.paging import NULL_PAGE, PageAllocator, PrefixTrie

__all__ = ["ServeEngine", "Request", "PageAllocator", "PrefixTrie",
           "NULL_PAGE", "bucket"]
