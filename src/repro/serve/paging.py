"""Host-side page bookkeeping for the continuous-batching serve engine.

Two small, pure-Python structures manage the device-resident page pool
that ``Model.init_page_pool`` allocates (see ``models/attention.py``):

  * :class:`PageAllocator` — a free list over page ids ``1..n_pages-1``
    with reference counts. Page 0 is the **null page**: inactive batch
    slots and unused page-table entries all point at it, so the packed
    decode gather is always in-bounds and never retraces. The allocator
    never hands out page 0.
  * :class:`PrefixTrie` — a trie over *page-sized token chunks* mapping
    prompt prefixes to the page ids that hold their K/V. Requests whose
    prompts share a prefix share those pages (each holder takes a
    refcount) instead of re-prefilling them. Sharing is at full-page
    granularity only, and a request never shares its *last* prompt
    position's page — the suffix handed to prefill is always >= 1 token
    and decode only ever appends to pages the request owns privately, so
    a shared page is written exactly once (by the request that first
    filled it) and copy-on-write never actually triggers.

Both structures are plain host state: they decide *which* page ids go
into the int32 page tables; the device only ever sees static-shape
gathers/scatters over the pool. Neither is thread-safe — the
:class:`~repro.serve.engine.ServeEngine` drives them from its single
scheduler loop.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

__all__ = ["PageAllocator", "PrefixTrie", "NULL_PAGE"]

NULL_PAGE = 0


class PageAllocator:
    """Free list + refcounts over page ids ``1..n_pages-1``.

    ``alloc`` returns a page with refcount 1 (or ``None`` when exhausted);
    ``incref`` adds a holder; ``decref`` drops one and returns the page to
    the free list when the count hits zero. Counters (``allocated`` /
    ``freed`` / ``peak_used``) feed the engine's serve report.
    """

    def __init__(self, n_pages: int):
        if n_pages < 2:
            raise ValueError(
                f"n_pages must be >= 2 (page 0 is the reserved null page), "
                f"got {n_pages}")
        self.n_pages = n_pages
        # LIFO free list: recently-freed pages are re-used first, which
        # keeps the working set of the device pool compact
        self._free = list(range(n_pages - 1, 0, -1))
        self._refs = [0] * n_pages
        self.allocated = 0
        self.freed = 0
        self.peak_used = 0

    @property
    def used(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    @property
    def free_count(self) -> int:
        return len(self._free)

    def refcount(self, pid: int) -> int:
        return self._refs[pid]

    def alloc(self) -> int | None:
        """Take a free page (refcount 1), or ``None`` when exhausted."""
        if not self._free:
            return None
        pid = self._free.pop()
        self._refs[pid] = 1
        self.allocated += 1
        self.peak_used = max(self.peak_used, self.used)
        return pid

    def incref(self, pid: int) -> None:
        if pid == NULL_PAGE or self._refs[pid] < 1:
            raise ValueError(f"incref on unallocated page {pid}")
        self._refs[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one holder; returns True when the page was freed."""
        if pid == NULL_PAGE or self._refs[pid] < 1:
            raise ValueError(f"decref on unallocated page {pid}")
        self._refs[pid] -= 1
        if self._refs[pid] == 0:
            self._free.append(pid)
            self.freed += 1
            return True
        return False

    def stats(self) -> dict:
        return {"n_pages": self.n_pages, "used": self.used,
                "free": self.free_count, "allocated": self.allocated,
                "freed": self.freed, "peak_used": self.peak_used}

    def __repr__(self) -> str:
        return (f"PageAllocator(used={self.used}/{self.n_pages - 1} "
                f"allocated={self.allocated} freed={self.freed})")


@dataclasses.dataclass
class _TrieNode:
    """One full page of prompt tokens: chunk-keyed children + the page id
    holding this chunk's K/V, plus an LRU tick for eviction ordering."""
    page: int
    tick: int
    children: dict[tuple, "_TrieNode"] = dataclasses.field(
        default_factory=dict)


class PrefixTrie:
    """Prompt-prefix -> page-id index at full-page granularity.

    Nodes are keyed by ``page_size``-token chunks; the path from the root
    to a node spells out a prompt prefix, and each node pins (one
    refcount on) the page holding that chunk's K/V. ``match`` walks the
    longest indexed prefix of a prompt; ``insert`` indexes a freshly
    prefilled prompt's full pages so later arrivals can share them;
    ``evict`` releases least-recently-matched pages nobody else holds
    when the allocator runs dry.

    The index is valid for **one (model, params) pair** — K/V bytes are a
    function of tokens *and* weights. The engine owns exactly one trie
    per served model; on a weight update the trie must be dropped.
    """

    def __init__(self, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.page_size = page_size
        self._root: dict[tuple, _TrieNode] = {}
        self._tick = 0
        self._n_pages = 0
        self.match_hits = 0      # match() calls that found >= 1 page
        self.pages_matched = 0   # total pages returned by match()
        self.pages_inserted = 0
        self.pages_evicted = 0

    def __len__(self) -> int:
        """Number of pages currently indexed (== trie-held refcounts)."""
        return self._n_pages

    def _chunks(self, tokens: Sequence[int]) -> Iterator[tuple]:
        ps = self.page_size
        for i in range(len(tokens) // ps):
            yield tuple(int(t) for t in tokens[i * ps:(i + 1) * ps])

    def match(self, tokens: Sequence[int],
              max_pages: int | None = None) -> list[int]:
        """Page ids of the longest indexed full-page prefix of ``tokens``.

        ``max_pages`` caps the walk — the engine passes
        ``(len(prompt) - 1) // page_size`` so the suffix handed to
        prefill keeps at least one token (the last-position logits must
        come from a real forward). Touches the matched nodes' LRU ticks.
        """
        self._tick += 1
        pids: list[int] = []
        level = self._root
        for chunk in self._chunks(tokens):
            if max_pages is not None and len(pids) >= max_pages:
                break
            node = level.get(chunk)
            if node is None:
                break
            node.tick = self._tick
            pids.append(node.page)
            level = node.children
        if pids:
            self.match_hits += 1
            self.pages_matched += len(pids)
        return pids

    def insert(self, tokens: Sequence[int], page_ids: Sequence[int],
               allocator: PageAllocator) -> int:
        """Index ``tokens``' full pages; returns how many were newly added.

        ``page_ids`` is the request's page table (covering *all* its
        prompt pages, shared first). Only the ``len(tokens) //
        page_size`` fully-covered pages are indexed — a partial last page
        will be appended to by decode, so its bytes are not a pure
        function of the prompt. Newly indexed pages take one trie-held
        refcount; chunks already present keep their existing page (the
        bytes are identical by construction).
        """
        self._tick += 1
        added = 0
        level = self._root
        for i, chunk in enumerate(self._chunks(tokens)):
            node = level.get(chunk)
            if node is None:
                pid = int(page_ids[i])
                allocator.incref(pid)
                node = _TrieNode(page=pid, tick=self._tick)
                level[chunk] = node
                added += 1
            else:
                node.tick = self._tick
            level = node.children
        self._n_pages += added
        self.pages_inserted += added
        return added

    def evict(self, allocator: PageAllocator, need: int) -> int:
        """Release up to ``need`` trie-only pages (refcount 1), LRU first.

        Only leaf nodes are candidates — dropping an interior node would
        orphan its (still-pinned) descendants from ``match``. Evicting a
        leaf can expose its parent, so the scan loops until ``need`` is
        met or nothing is evictable. Returns the number of pages freed.
        """
        freed = 0
        while freed < need:
            victim = self._find_lru_leaf(allocator)
            if victim is None:
                break
            parent, key = victim
            node = parent[key]
            del parent[key]
            allocator.decref(node.page)
            self._n_pages -= 1
            self.pages_evicted += 1
            freed += 1
        return freed

    def _find_lru_leaf(self, allocator: PageAllocator):
        """(parent-dict, chunk-key) of the oldest evictable leaf, or None."""
        best = None
        best_tick = None
        stack: list[tuple[dict, tuple, _TrieNode]] = [
            (self._root, k, n) for k, n in self._root.items()]
        while stack:
            parent, key, node = stack.pop()
            if node.children:
                stack.extend((node.children, k, n)
                             for k, n in node.children.items())
            elif allocator.refcount(node.page) == 1:
                if best_tick is None or node.tick < best_tick:
                    best, best_tick = (parent, key), node.tick
        return best

    def stats(self) -> dict:
        return {"pages": self._n_pages, "match_hits": self.match_hits,
                "pages_matched": self.pages_matched,
                "pages_inserted": self.pages_inserted,
                "pages_evicted": self.pages_evicted}

    def __repr__(self) -> str:
        return (f"PrefixTrie(pages={self._n_pages} "
                f"hits={self.match_hits} evicted={self.pages_evicted})")
