"""Training launcher CLI.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --steps 200 --seq 512 --batch 32 --ckpt /tmp/run1 [--reduced]

Resumable: rerunning with the same --ckpt continues from the latest
checkpoint; crashes restart through the fault policy (max 3 retries).
"""
from __future__ import annotations

import argparse
import logging

from repro.configs import get_config, get_reduced
from repro.distributed.fault import run_with_restarts
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--reduced", action="store_true",
                    help="width-reduced config (CPU-friendly)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)

    def loop(_attempt):
        _, hist = train(cfg, seq_len=args.seq, global_batch=args.batch,
                        steps=args.steps, ckpt_dir=args.ckpt,
                        ckpt_every=args.ckpt_every, lr=args.lr,
                        seed=args.seed,
                        metrics_path=(f"{args.ckpt}/metrics.jsonl"
                                      if args.ckpt else None))
        return hist

    hist, restarts = run_with_restarts(loop, max_restarts=3)
    print(f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"({len(hist)} steps this attempt, {restarts} restarts)")


if __name__ == "__main__":
    main()
