import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this runs ``jax.jit(step).lower(...).compile()`` under the
production mesh — 16x16 single-pod and 2x16x16 multi-pod — and records
memory_analysis(), cost_analysis() and the collective schedule parsed from
the post-SPMD HLO. Failures (sharding mismatch, OOM-at-compile, unsupported
collectives) are system bugs and are recorded as such.

Usage:
  python -m repro.launch.dryrun --arch all --shape all --mesh both \
      --out results/dryrun.json
  python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (collective_bytes, model_flops,
                                   roofline_terms)
from repro.launch import specs as S

DRYRUN_ARCHS = [a for a in ARCHS if a != "llama1_7b"]  # 10 assigned archs


def cell_skip_reason(cfg, shape) -> str | None:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention at 524k context; run only for "
                "sub-quadratic archs (DESIGN.md §5)")
    return None


def lower_train(cfg, shape, mesh):
    from repro.train.train_step import make_train_step
    from repro.optim.schedule import cosine_schedule
    model, opt, sshape, bshape, sspec, bspec = S.train_cell_specs(
        cfg, shape, mesh)
    step = make_train_step(model, opt, cosine_schedule(3e-4, 100, 10000))
    return jax.jit(step, in_shardings=(sspec, bspec),
                   donate_argnums=0).lower(sshape, bshape)


def lower_decode(cfg, shape, mesh):
    scfg = S.serve_config(cfg)
    model, pshape, cshape, tok, pspec, cspec, tspec = S.serve_cell_specs(
        scfg, shape, mesh)

    def decode(params, caches, token, step):
        return model.decode_step(params, caches, token, step)

    return jax.jit(decode,
                   in_shardings=(pspec, cspec, tspec, None),
                   donate_argnums=1).lower(
        pshape, cshape, tok, jax.ShapeDtypeStruct((), jnp.int32))


def lower_prefill(cfg, shape, mesh):
    scfg = S.serve_config(cfg)
    model, pshape, batch, s_eff, pspec, bspec = S.prefill_cell_specs(
        scfg, shape, mesh)

    def prefill(params, batch):
        return model.prefill(params, batch, s_eff)

    return jax.jit(prefill, in_shardings=(pspec, bspec)).lower(pshape, batch)


def run_cell(arch: str, shape_name: str, multi_pod: bool) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = 512 if multi_pod else 256
    rec = {"arch": cfg.name, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "devices": n_dev, "kind": shape.kind}
    reason = cell_skip_reason(cfg, shape)
    if reason:
        rec["status"] = "skipped"
        rec["reason"] = reason
        return rec
    if cfg.max_target_positions and shape.seq_len > cfg.max_target_positions:
        rec["note"] = (f"seq clamped to architectural max "
                       f"{cfg.max_target_positions} (+{cfg.n_context_tokens}"
                       f" encoder frames)")
    t0 = time.time()
    try:
        with jax_compat.set_mesh(mesh):
            if shape.kind == "train":
                lowered = lower_train(cfg, shape, mesh)
            elif shape.kind == "prefill":
                lowered = lower_prefill(cfg, shape, mesh)
            else:
                lowered = lower_decode(cfg, shape, mesh)
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        coll = collective_bytes(compiled.as_text())
        flops = float(ca.get("flops", 0.0))
        byts = float(ca.get("bytes accessed", 0.0))
        terms = roofline_terms(flops, byts, coll["total"])
        mf = model_flops(cfg, shape)
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "arg_bytes_per_dev": int(ma.argument_size_in_bytes),
            "temp_bytes_per_dev": int(ma.temp_size_in_bytes),
            "out_bytes_per_dev": int(ma.output_size_in_bytes),
            "hlo_flops_per_dev": flops,
            "hlo_bytes_per_dev": byts,
            "collectives": {k: coll[k] for k in
                            ("all-reduce", "all-gather", "reduce-scatter",
                             "all-to-all", "collective-permute", "total",
                             "count")},
            "model_flops_global": mf,
            "useful_flops_ratio": (mf / (flops * n_dev))
            if flops else 0.0,
            **terms,
        })
    except Exception as e:  # a failed cell is a bug — record it loudly
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all",
                    choices=["all", *SHAPES.keys()])
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    archs = DRYRUN_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(args.out):
        for r in json.load(open(args.out)):
            existing[(r["arch"], r["shape"], r["mesh"])] = r

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                cfgname = get_config(arch).name
                key = (cfgname, shape, "2x16x16" if mp else "16x16")
                if key in existing and existing[key].get("status") == "ok":
                    records.append(existing[key])
                    print(f"[cached] {key}")
                    continue
                rec = run_cell(arch, shape, mp)
                records.append(rec)
                status = rec["status"]
                extra = (f"compile {rec.get('compile_s')}s "
                         f"dom={rec.get('dominant')}"
                         if status == "ok" else rec.get("error", rec.get(
                             "reason", "")))[:110]
                print(f"[{status:7s}] {key} {extra}", flush=True)
                # merge + persist incrementally
                existing[key] = rec
                with open(args.out, "w") as f:
                    json.dump(list(existing.values()), f, indent=1)
    ok = sum(r["status"] == "ok" for r in records)
    sk = sum(r["status"] == "skipped" for r in records)
    fl = sum(r["status"] == "fail" for r in records)
    print(f"\n{ok} ok / {sk} skipped / {fl} FAILED "
          f"of {len(records)} cells -> {args.out}")
    return 1 if fl else 0


if __name__ == "__main__":
    raise SystemExit(main())
