"""Serving launcher CLI — batched prefill + greedy decode through the
Transitive-Array path (W4A8 TransitiveLinear + dynamic int8 attention +
KV8 cache).

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 16 --gen 16 [--w-bits 4] [--path engine]

``--path engine`` serves through the plan-cached Scoreboard forest: every
layer's ExecutionPlan is built exactly once (offline precompile over the
params pytree), decode is run-only, and the report splits plan-build time
from decode time and prints the cache counters (misses == distinct
quantized weights, hits == remaining engine forward calls).

``--path engine_jit`` (and ``engine_pallas``) go further: the compiled
plans are **device-resident** — embedded into the params pytree
(``Model.attach_device_plans``) so the block scan slices them alongside
the weights — and decode runs pure JAX with zero host callbacks.
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.launch.specs import serve_config
from repro.models.model import Model
from repro.train.serve_step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--w-bits", type=int, default=4, choices=(4, 8))
    ap.add_argument("--path", default="int_dot",
                    choices=("int_dot", "lut", "pallas", "engine",
                             "engine_jit", "engine_pallas"),
                    help="integer-GEMM execution path for PTQ linears")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--fp", action="store_true",
                    help="serve unquantized (baseline comparison)")
    ap.add_argument("--no-precompile", action="store_true",
                    help="skip the offline plan warmup (engine path only; "
                    "plans then build lazily on first forward per weight)")
    args = ap.parse_args()

    base = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = base if args.fp else serve_config(base, w_bits=args.w_bits,
                                            path=args.path)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine_path = not args.fp and args.path in ("engine", "engine_jit",
                                                "engine_pallas")
    device_path = engine_path and args.path != "engine"
    plan_stats, t_plan, t_attach = {}, 0.0, 0.0
    if engine_path:
        from repro.core import plancache
        cache = plancache.default_cache()
        cache.reset_stats()
        if not args.no_precompile:
            t0 = time.time()
            plan_stats = model.precompile_plans(params)
            t_plan = time.time() - t0
        if device_path:
            # device paths need plans as traced data inside the block scan;
            # attach builds any still-missing plan through the same cache
            t0 = time.time()
            params = model.attach_device_plans(params)
            t_attach = time.time() - t0

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)}
    if cfg.n_context_tokens or cfg.is_encdec:
        batch["context"] = jax.random.normal(
            key, (args.batch, cfg.n_context_tokens, cfg.d_model),
            jnp.float32) * 0.02

    max_len = args.prompt_len + args.gen + 8
    t0 = time.time()
    toks = greedy_generate(model, params, batch, max_len=max_len,
                           n_steps=args.gen)
    dt = time.time() - t0
    mode = "fp" if args.fp else f"W{args.w_bits}A8+KV8/{args.path}"
    print(f"[{cfg.name} | {mode}] generated {args.batch}x{args.gen} tokens "
          f"in {dt:.2f}s")
    if engine_path:
        s = cache.stats()
        attach = (f" + device-plan attach {t_attach:.2f}s"
                  if device_path else "")
        decode = ("pure-JAX, zero host callbacks" if device_path
                  else "run-only")
        print(f"[plan cache] offline plan-build {t_plan:.2f}s "
              f"({plan_stats.get('plans', 0)} plans over "
              f"{plan_stats.get('layers', 0)} stacked layer weights)"
              f"{attach} | decode {dt:.2f}s {decode}")
        print(f"[plan cache] misses={s['misses']} hits={s['hits']} "
              f"evictions={s['evictions']} size={s['size']}")
        if s["misses"] != plan_stats.get("built", s["misses"]):
            print("[plan cache] WARNING: plans were built during decode — "
                  "re-planning leaked back into the hot path")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
