"""Serving launcher CLI — batched prefill + greedy decode through the
Transitive-Array path (W4A8 TransitiveLinear + dynamic int8 attention +
KV8 cache).

  PYTHONPATH=src python -m repro.launch.serve --arch chatglm3-6b --reduced \
      --batch 4 --prompt-len 16 --gen 16 [--w-bits 4] [--backend engine]

``--backend`` takes any name from the execution-backend registry
(``repro.core.backend.list_backends()`` — the choice list below is
enumerated from it, not hardcoded). What the launcher does follows the
backend's declared capabilities:

  * ``needs_plan`` backends (the engine family) serve plan-cached: every
    layer's ExecutionPlan is built exactly once (offline precompile over
    the params pytree), decode is run-only, and the report splits
    plan-build time from decode time and prints the cache counters
    (misses == distinct quantized weights, hits == remaining engine
    forward calls) — per backend.
  * ``device_resident`` planned backends additionally get their compiled
    plans embedded into the params pytree (``Model.attach_device_plans``)
    so the block scan slices them alongside the weights — decode runs
    pure JAX with zero host callbacks.

``--mesh data=N`` serves on a device mesh — the multi-device serve cell:
the batch is sharded ``P("data")`` end-to-end through prefill + decode
(``greedy_generate(mesh=)``), and device-resident backends attach their
DevicePlans placed on the mesh (replicated by default — each backend's
``plan_specs`` capability hook decides). Tokens are bit-identical to the
1-device run. On a CPU host, fake the devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the CI smoke).

``--continuous`` switches from the one-shot batched generate to the
continuous-batching serve engine (``repro.serve.ServeEngine``): requests
arrive staggered (``--requests`` of them, one every ``--arrive-every``
host steps), are admitted into ``--slots`` packed decode slots over a
paged KV pool (``--page-size`` tokens per page), and prompts sharing a
prefix share pages through the prefix trie instead of re-prefilling. The
report prints per-request TTFT/latency, aggregate tokens/s, and the
prefix-reuse counters. Tokens stay bit-identical to running each request
alone through the one-shot path.

``--lint`` runs the tracelint preflight (``repro.analysis``) over the
selected backend's serving programs under the selected mesh before any
weight is initialised — plus the plan-IR verifier (``planlint``) over
the backend's plan artifacts — and refuses to serve on any error
finding: the same gate CI runs, one flag away at launch time.

Fleet flags (docs/FLEET.md):

  * ``--role planner --bundle-dir D`` plans + compiles every layer once
    and writes fingerprinted plan bundles to ``D`` (no serving);
    ``--role server --bundle-dir D`` attaches those bundles instead of
    planning — zero plan builds on the serve cell, refusal if the
    bundle's weight fingerprint / config / backend don't match.
  * ``--watch-weights D`` (with ``--continuous``) serves through a live
    weight update: a ``ReplanWorker`` rebuilds plans on a background
    thread when a new checkpoint lands in ``D`` and the engine hot-swaps
    at a step boundary — in-flight requests finish on the weights that
    admitted them, decode is not retraced. The launcher itself stages
    the update (re-init with ``--swap-seed`` written as a checkpoint
    after ``--swap-after`` host steps) so the swap is reproducible;
    ``--assert-swap-identity`` then checks every finished request
    bit-matches the one-shot path on its own generation's weights.

``--path`` is the deprecated spelling of ``--backend``.
"""
from __future__ import annotations

import argparse
import time
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.core.backend import get_backend, list_backends
from repro.launch.mesh import make_serve_mesh
from repro.launch.specs import mesh_decode_report, serve_config
from repro.models.model import Model
from repro.train.serve_step import greedy_generate


def _serve_continuous(model, params, cfg, args, mesh, name,
                      raw_params=None):
    """Continuous-batching serve: staggered arrivals through ServeEngine.

    With ``--watch-weights`` the launcher stages a live weight update mid
    run: half the requests are admitted on generation 0, a fresh
    checkpoint is written after ``--swap-after`` host steps, the
    ``WeightWatcher``/``ReplanWorker`` pair rebuilds plans off-thread
    while the engine keeps stepping, and the remaining requests land on
    generation 1 after the atomic swap.
    """
    from repro.serve import ServeEngine

    ps = args.page_size
    max_len = -(-(args.prompt_len + args.gen) // ps) * ps
    eng = ServeEngine(model, params, n_slots=args.slots, max_len=max_len,
                      page_size=ps, mesh=mesh,
                      paged_kernel=args.paged_kernel,
                      bucket_prefill=not args.no_bucket_prefill)
    rng = np.random.default_rng(1)
    base = rng.integers(0, cfg.vocab, size=args.prompt_len).tolist()
    # arrival pattern with real prefix structure: even requests replay the
    # base prompt (full-prefix hit after the first), odd ones keep only the
    # first half (partial hit at page granularity)
    prompts = [list(base) if i % 2 == 0 else
               base[:args.prompt_len // 2] + rng.integers(
                   0, cfg.vocab,
                   size=args.prompt_len - args.prompt_len // 2).tolist()
               for i in range(args.requests)]

    hot = args.watch_weights
    worker = watcher = None
    gen_raw = {0: raw_params}
    failures = []
    if hot:
        from repro.distributed import checkpoint
        from repro.fleet import ReplanWorker, WeightWatcher

        def _on_ready(g):
            new_gen = eng.swap_params(g.params, tag=g.tag)
            print(f"[hotswap] generation {new_gen} staged "
                  f"(checkpoint step {g.tag}, build {g.build_s:.2f}s, "
                  f"{g.plans_built} plan builds, off-thread)")

        def _on_error(e):
            failures.append(e)
            print(f"[hotswap] replan FAILED — previous generation keeps "
                  f"serving (rollback): {e}")

        worker = ReplanWorker(model, mesh=mesh, reference=params,
                              on_ready=_on_ready, on_error=_on_error)
        watcher = WeightWatcher(hot, raw_params, worker)
        # only react to checkpoints newer than whatever the dir holds now
        watcher.seen_step = checkpoint.latest_step(hot)
        new_raw = model.init(jax.random.PRNGKey(args.swap_seed))
        gen_raw[1] = new_raw
        ckpt_written = False

    # with a staged swap, the second half of the requests waits for gen 1
    first = (args.requests + 1) // 2 if hot else args.requests
    submitted = host_step = 0
    t0 = time.time()
    while (submitted < args.requests or eng.queue or eng.active
           or (hot and eng.generation == 0 and not failures)):
        limit = (first if (hot and eng.generation == 0)
                 else args.requests)
        if (submitted < limit
                and host_step >= submitted * args.arrive_every):
            eng.submit(prompts[submitted], args.gen)
            submitted += 1
        if hot:
            if not ckpt_written and host_step >= args.swap_after:
                step = (watcher.seen_step or 0) + 1
                checkpoint.save(hot, step, new_raw)
                ckpt_written = True
                print(f"[hotswap] new weights written as checkpoint "
                      f"step {step} at host step {host_step}")
            watcher.poll()
        eng.step()
        host_step += 1
    if worker is not None:
        worker.stop()
    dt = time.time() - t0
    rep = eng.report()
    mode = "fp" if args.fp else f"W{args.w_bits}A8+KV8/{name}"
    print(f"[{cfg.name} | {mode} | continuous] {rep['n_requests']} requests "
          f"x {args.gen} tokens (staggered every {args.arrive_every} steps, "
          f"{args.slots} slots, page_size={ps}) in {dt:.2f}s -> "
          f"{rep['tokens_per_s']:.1f} tok/s")
    for r in rep["requests"]:
        print(f"  req {r['rid']}: prompt={r['prompt_len']} "
              f"tokens={r['n_tokens']} shared_pages={r['shared_pages']} "
              f"prefill_computed={r['prefill_computed']} "
              f"ttft={r['ttft_s'] * 1e3:.1f}ms "
              f"latency={r['latency_s'] * 1e3:.1f}ms")
    c = rep["counters"]
    print(f"[prefix reuse] hits={c['prefix_hits']} "
          f"pages_shared={c['pages_shared']} "
          f"prefill_skipped={c['prefill_skipped']} "
          f"prefill_computed={c['prefill_computed']} | "
          f"pages={c['pages']} trie={c['trie']}")
    print(f"[fast path] decode={'pallas-kernel' if args.paged_kernel else 'gather'} "
          f"prefill={'per-request' if args.no_bucket_prefill else 'bucketed'} | "
          f"jit traces: prefill={c['prefill_traces']} "
          f"decode={c['decode_traces']} bucket_hits={c['bucket_hits']} "
          f"batched_calls={c['prefill_batched_calls']} "
          f"pad_rows={c['prefill_pad_rows']}")
    for r in eng.finished:
        gen = f" gen={r.gen}" if hot else ""
        print(f"  req {r.rid}:{gen} {r.tokens}")
    if hot:
        _hotswap_report(model, eng, args, failures, gen_raw, worker)
    return eng


def _hotswap_report(model, eng, args, failures, gen_raw, worker):
    """Print the swap outcome; with --assert-swap-identity, bit-compare
    every finished request against the one-shot path on its own
    generation's weights (SystemExit on any mismatch or failed build)."""
    s = eng.stats()
    print(f"[hotswap] generation={s['generation']} "
          f"swaps={eng.counters['swaps']} "
          f"retired={eng.counters['generations_retired']} "
          f"decode_jit_traces={s['decode_jit_traces']} "
          f"prefill_jit_traces={s['prefill_jit_traces']} | "
          f"worker: {worker.stats()}")
    if failures:
        if args.assert_swap_identity:
            raise SystemExit(f"[hotswap] replan failed: {failures[0]}")
        return
    if not args.assert_swap_identity:
        return
    # 1-device references, as in the serve-engine tests: the request
    # alone through greedy_generate on its generation's weights (plans
    # re-attached without the mesh — bit-identical by the mesh contract)
    ps = args.page_size
    max_len = -(-(args.prompt_len + args.gen) // ps) * ps
    ref_params = {g: model.attach_device_plans(raw)
                  for g, raw in gen_raw.items() if raw is not None}
    gens_seen = sorted({r.gen for r in eng.finished})
    bad = 0
    for r in eng.finished:
        if r.gen not in ref_params:
            continue
        batch = {"tokens": jnp.asarray([list(r.prompt)], jnp.int32)}
        want = np.asarray(greedy_generate(
            model, ref_params[r.gen], batch, max_len=max_len,
            n_steps=r.max_new_tokens))[0]
        got = np.asarray(r.tokens)
        if got.shape != want.shape or not np.array_equal(got, want):
            bad += 1
            print(f"[hotswap] MISMATCH req {r.rid} (gen {r.gen}): "
                  f"{got} != {want}")
    if bad or s["generation"] < 1:
        raise SystemExit(
            f"[hotswap] identity check FAILED: {bad} mismatching "
            f"request(s), final generation {s['generation']}")
    print(f"[hotswap] identity OK: {len(eng.finished)} request(s) across "
          f"generations {gens_seen} each bit-match the one-shot path on "
          f"their own weights")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--w-bits", type=int, default=4, choices=(4, 8))
    ap.add_argument("--backend", default=None, choices=list_backends(),
                    help="integer-GEMM execution backend for PTQ linears "
                    "(registry: repro.core.backend)")
    ap.add_argument("--path", default=None, choices=list_backends(),
                    help="DEPRECATED alias for --backend")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None, metavar="AXIS=N[,AXIS=N]",
                    help="serve on a device mesh, e.g. 'data=4' — batch "
                    "sharded P('data') through prefill+decode, DevicePlans "
                    "attached on the mesh (CPU: set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=N)")
    ap.add_argument("--fp", action="store_true",
                    help="serve unquantized (baseline comparison)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching through the paged-KV serve "
                    "engine: staggered request arrivals, packed decode "
                    "slots, prefix-trie page sharing")
    ap.add_argument("--requests", type=int, default=4,
                    help="(--continuous) number of requests to submit")
    ap.add_argument("--arrive-every", type=int, default=2,
                    help="(--continuous) host steps between arrivals")
    ap.add_argument("--page-size", type=int, default=8,
                    help="(--continuous) tokens per KV page")
    ap.add_argument("--slots", type=int, default=2,
                    help="(--continuous) packed decode batch slots")
    ap.add_argument("--paged-kernel", action="store_true",
                    help="(--continuous) decode attention through the "
                    "Pallas live-page kernel (kernels/paged_attention) "
                    "instead of the full-extent gather oracle")
    ap.add_argument("--no-bucket-prefill", action="store_true",
                    help="(--continuous) disable bucketed batched prefill "
                    "(revert to per-request batch-1 prefills)")
    ap.add_argument("--lint", action="store_true",
                    help="tracelint preflight: before serving, lint the "
                    "selected backend's serving programs (prefill / "
                    "donated decode / paged decode / paged-attention "
                    "kernel / bucketed prefill / forest) under the "
                    "selected mesh and refuse to serve on any error "
                    "finding (rule catalog: docs/ANALYSIS.md)")
    ap.add_argument("--no-precompile", action="store_true",
                    help="skip the offline plan warmup (planned backends "
                    "only; plans then build lazily on first forward per "
                    "weight)")
    ap.add_argument("--bundle-dir", default=None, metavar="DIR",
                    help="plan-bundle directory for --role (docs/FLEET.md)")
    ap.add_argument("--role", default=None, choices=("planner", "server"),
                    help="planner: plan once + write bundles to "
                    "--bundle-dir and exit; server: attach plans from "
                    "--bundle-dir instead of planning (zero plan builds, "
                    "fingerprint-checked)")
    ap.add_argument("--watch-weights", default=None, metavar="DIR",
                    help="(--continuous) hot-swap drill: watch DIR for "
                    "new weight checkpoints, re-plan off-thread and swap "
                    "at a step boundary; the launcher writes the new "
                    "checkpoint itself after --swap-after host steps")
    ap.add_argument("--swap-after", type=int, default=3,
                    help="(--watch-weights) host steps before the new "
                    "weights checkpoint is written")
    ap.add_argument("--swap-seed", type=int, default=1234,
                    help="(--watch-weights) PRNG seed for the new "
                    "weights (re-init; any seed != 0 is a real update)")
    ap.add_argument("--assert-swap-identity", action="store_true",
                    help="(--watch-weights) exit non-zero unless every "
                    "finished request bit-matches the one-shot path on "
                    "its own generation's weights")
    args = ap.parse_args()
    if args.role is not None and not args.bundle_dir:
        ap.error(f"--role {args.role} needs --bundle-dir")
    if args.watch_weights and not args.continuous:
        ap.error("--watch-weights needs --continuous (the hot-swap "
                 "protocol lives on the serve engine)")
    if args.role is not None and args.fp:
        ap.error("plan bundles carry quantized-weight plans; drop --fp")

    name = args.backend or "int_dot"
    if args.path is not None:
        warnings.warn("--path is deprecated; use --backend",
                      DeprecationWarning)
        name = args.path if args.backend is None else name
    backend = get_backend(name)

    mesh = make_serve_mesh(args.mesh) if args.mesh else None

    if args.lint:
        # preflight on the reduced arch (same programs, small trace): the
        # invariants are structural, so a violation there is a violation
        # at full size too — and the gate stays cheap enough to be on.
        from repro.analysis.programs import lint_backend
        t0 = time.time()
        _, findings = lint_backend(name, mesh=mesh, arch=args.arch,
                                   batch=args.batch,
                                   w_bits=args.w_bits)
        # plan-IR half of the preflight: the same verifier that gates
        # cache publish / bundle load / swap staging, run proactively
        from repro.analysis.planlint import lint_plans
        _, pfindings = lint_plans([name], mesh=mesh)
        findings = list(findings) + list(pfindings)
        errors = [f for f in findings if f.severity == "error"]
        for f in findings:
            print(f"[tracelint] {f.format()}")
        print(f"[tracelint] preflight {name}: {len(findings)} finding(s) "
              f"({time.time() - t0:.1f}s)")
        if errors:
            ap.error(f"tracelint preflight failed with {len(errors)} "
                     f"error finding(s); serve refused (run python -m "
                     f"repro.analysis.lint --backend {name} to inspect)")

    base = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    cfg = base if args.fp else serve_config(base, w_bits=args.w_bits,
                                            backend=name)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    raw_params = params

    planned = not args.fp and backend.needs_plan
    device_path = planned and backend.device_resident

    if args.role == "planner":
        from repro.fleet import write_bundles
        try:
            manifest = write_bundles(params, cfg.quant, args.bundle_dir,
                                     backend=name)
        except ValueError as e:
            ap.error(str(e))
        print(f"[planner] {args.bundle_dir}: {manifest['n_files']} bundle "
              f"file(s) over {manifest['n_layers']} layer(s), backend="
              f"{manifest['backend']}, weights="
              f"{manifest['weights_fingerprint'][:12]} "
              f"({manifest['plan_wall_s']:.2f}s plan+compile)")
        return

    plan_stats, t_plan, t_attach = {}, 0.0, 0.0
    if planned:
        from repro.core import plancache
        cache = plancache.default_cache()
        cache.reset_stats()
    if args.role == "server":
        if not device_path:
            ap.error(f"--role server attaches device plan bundles; "
                     f"backend '{name}' does not execute from them")
        from repro.core.engine import BundleMismatchError
        from repro.fleet import read_manifest, load_bundles
        t0 = time.time()
        try:
            params = load_bundles(params, cfg.quant, args.bundle_dir,
                                  mesh=mesh)
        except (FileNotFoundError, BundleMismatchError) as e:
            raise SystemExit(f"[server] bundle refused: {e}")
        t_attach = time.time() - t0
        s = cache.stats()
        print(f"[server] attached {read_manifest(args.bundle_dir)['n_files']} "
              f"bundle(s) from {args.bundle_dir} in {t_attach:.2f}s | "
              f"plan builds on this cell: {s['misses']}")
        if s["misses"]:
            raise SystemExit("[server] bundle attach built plans locally "
                             "— the planner artifact is incomplete")
    elif planned:
        if not args.no_precompile:
            t0 = time.time()
            plan_stats = model.precompile_plans(params)
            t_plan = time.time() - t0
        if device_path:
            # device-resident backends need plans as traced data inside the
            # block scan; attach builds any still-missing plan through the
            # same cache. With a mesh the plan leaves are placed on it —
            # the backend's plan_specs hook decides the layout (built-ins
            # replicate: every device runs every layer on its batch shard).
            t0 = time.time()
            params = model.attach_device_plans(params, mesh=mesh)
            t_attach = time.time() - t0

    if args.continuous:
        reason = model.supports_paged()
        if reason is not None:
            ap.error(f"--continuous needs the paged serve path: {reason}")
        _serve_continuous(model, params, cfg, args, mesh, name,
                          raw_params=raw_params)
        if planned:
            s = cache.stats()
            print(f"[plan cache] offline plan-build {t_plan:.2f}s | "
                  f"misses={s['misses']} hits={s['hits']}")
        return

    key = jax.random.PRNGKey(1)
    batch = {"tokens": jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab, jnp.int32)}
    if cfg.n_context_tokens or cfg.is_encdec:
        batch["context"] = jax.random.normal(
            key, (args.batch, cfg.n_context_tokens, cfg.d_model),
            jnp.float32) * 0.02

    max_len = args.prompt_len + args.gen + 8
    t0 = time.time()
    # n_steps is the number of generated tokens (prefill argmax + gen-1
    # decode steps — the explicit greedy_generate contract)
    toks = greedy_generate(model, params, batch, max_len=max_len,
                           n_steps=args.gen, mesh=mesh)
    dt = time.time() - t0
    mode = "fp" if args.fp else f"W{args.w_bits}A8+KV8/{name}"
    print(f"[{cfg.name} | {mode}] generated {args.batch}x{args.gen} tokens "
          f"in {dt:.2f}s")
    if mesh is not None:
        print(mesh_decode_report(mesh, args.batch, args.gen, dt))
    if planned:
        s = cache.stats()
        attach = (f" + device-plan attach {t_attach:.2f}s"
                  if device_path else "")
        decode = ("pure-JAX, zero host callbacks" if device_path
                  else "run-only")
        print(f"[plan cache] offline plan-build {t_plan:.2f}s "
              f"({plan_stats.get('plans', 0)} plans over "
              f"{plan_stats.get('layers', 0)} stacked layer weights)"
              f"{attach} | decode {dt:.2f}s {decode}")
        print(f"[plan cache] misses={s['misses']} hits={s['hits']} "
              f"evictions={s['evictions']} size={s['size']}")
        for bname, bs in sorted(s["backends"].items()):
            print(f"[plan cache]   {bname}: misses={bs['misses']} "
                  f"hits={bs['hits']}")
        if s["misses"] != plan_stats.get("built", s["misses"]):
            print("[plan cache] WARNING: plans were built during decode — "
                  "re-planning leaked back into the hot path")
    print(np.asarray(toks))


if __name__ == "__main__":
    main()
