"""ShapeDtypeStruct stand-ins + shardings for every (arch × shape) cell.

No device allocation happens here: params/opt/caches come from
jax.eval_shape, batches from ShapeDtypeStructs, shardings from the logical
rules in distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed.sharding import param_specs, spec
from repro.models.model import Model
from repro.quant import QuantConfig

__all__ = ["serve_config", "train_cell_specs", "serve_cell_specs",
           "named", "cache_specs", "mesh_decode_report"]


def serve_config(cfg: ModelConfig, w_bits: int = 4,
                 backend: str = "int_dot",
                 path: str | None = None) -> ModelConfig:
    """Serving variant: the paper's technique on — PTQ W4A8 linears
    (per-channel epilogue scales at scale) + dynamic int8 attention.

    ``backend`` names the integer-GEMM execution backend (any
    ``repro.core.backend`` registry name — enumerate with
    ``list_backends()``); all are bit-exact on the int32 accumulator.
    Planned backends serve through the plan-cached Scoreboard forest
    (core/plancache.py). ``path=`` is the deprecated spelling."""
    if path is not None:
        import warnings
        warnings.warn("serve_config(path=...) is deprecated; use "
                      "backend=...", DeprecationWarning, stacklevel=2)
        backend = path
    return cfg.replace(
        quant=QuantConfig(mode="ptq", w_bits=w_bits, a_bits=8, group=0,
                          backend=backend),
        quant_attention=not cfg.is_encdec,
        kv_cache_bits=8 if not cfg.is_encdec else 16,
        remat="none")


def named(mesh, spec_tree):
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), spec_tree)


def mesh_decode_report(mesh, batch: int, n_tokens: int, dt: float) -> str:
    """One-line per-device decode summary for the mesh serve cell.

    ``dt`` is wall time for ``batch`` sequences x ``n_tokens`` greedy
    tokens — prefill and first-call jit compile included, so this is the
    end-to-end number, not steady-state decode (that lives in
    ``bench_kernel --serve-bench``'s per-backend ``mesh_decode_us``).
    Under data parallelism wall time is shared by all devices; the line
    additionally says how many batch rows each device carried (or that
    the batch replicated — the extent did not divide)."""
    shape = dict(mesh.shape)
    dp = _axis_size(mesh, _batch_axes(mesh))
    axes = ",".join(f"{a}={s}" for a, s in shape.items())
    if dp > 1 and batch % dp == 0:
        rows = f"{batch // dp} batch rows/device"
    elif dp > 1:
        rows = f"batch {batch} REPLICATED ({dp} does not divide it)"
    else:
        rows = "no data axes > 1"
    per_tok_ms = dt / max(n_tokens, 1) * 1e3
    return (f"[mesh] {axes} ({mesh.devices.size} devices) | {rows} | "
            f"{batch}x{n_tokens} tokens in {dt:.2f}s "
            f"({per_tok_ms:.1f} ms/token wall incl. prefill+compile; "
            f"steady-state: bench mesh_decode_us)")


def _batch_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, axes) -> int:
    shape = dict(mesh.shape)
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= shape.get(a, 1)
    return n


def _fit(parts, shape, mesh) -> P:
    """Drop spec axes whose mesh extent does not divide the dim."""
    fitted = []
    for dim, part in zip(shape, parts):
        if part is None:
            fitted.append(None)
        elif dim % _axis_size(mesh, part) == 0:
            fitted.append(part)
        else:
            fitted.append(None)
    return P(*fitted)


def effective_accum(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """grad_accum capped so each microbatch still covers the DP extent."""
    dp = _axis_size(mesh, _batch_axes(mesh))
    accum = max(1, min(cfg.grad_accum, shape.global_batch // max(dp, 1)))
    while shape.global_batch % (accum * dp) and accum > 1:
        accum -= 1
    return accum


def cache_specs(cfg: ModelConfig, caches_shape, mesh):
    """Sharding rules for serve caches: KV heads on "model" when divisible,
    else the cache sequence axis (sequence parallelism); recurrent state
    shards its feature axis."""
    model_n = mesh.shape.get("model", 1) if hasattr(mesh.shape, "get") \
        else dict(mesh.shape)["model"]
    dp = _batch_axes(mesh)

    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        shp = leaf.shape
        if name in ("k", "v", "ks", "vs") and len(shp) >= 4:
            # (R?, B, S, KV, HD|1) — values and their KV8 scales
            lead = (None,) * (len(shp) - 4)
            if shp[-2] % model_n == 0:
                parts = (*lead, dp, None, "model", None)
            elif shp[-3] % model_n == 0:
                parts = (*lead, dp, "model", None, None)
            else:
                parts = (*lead, dp, None, None, None)
        elif name == "C" and len(shp) >= 5:     # mLSTM (R?, B, H, dk, dv)
            lead = (None,) * (len(shp) - 4)
            parts = (*lead, dp, "model", None, None)
        elif name == "n" and len(shp) >= 4:     # mLSTM (R?, B, H, dk)
            lead = (None,) * (len(shp) - 3)
            parts = (*lead, dp, "model", None)
        else:                                   # recurrent vectors (R?, B, D)
            lead = (None,) * (len(shp) - 2)
            parts = (*lead, dp, "model")
        return _fit(parts, shp, mesh)

    return jax.tree_util.tree_map_with_path(one, caches_shape)


def train_cell_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """(model, opt, state_shapes, batch_shapes, state_shardings,
    batch_shardings) with grad_accum fitted to the mesh's DP extent."""
    from repro.train.train_step import make_optimizer, state_shape
    from repro.data.pipeline import batch_specs
    accum = effective_accum(cfg, shape, mesh)
    cfg = cfg.replace(grad_accum=accum)
    model = Model(cfg)
    opt = make_optimizer(cfg)
    sshape = state_shape(model, opt)
    sspec = {"params": param_specs(sshape["params"]),
             "opt": {"m": param_specs(sshape["opt"]["m"]),
                     "v": param_specs(sshape["opt"]["v"]),
                     "count": P()},
             "step": P()}
    bshape = batch_specs(cfg, shape)
    dp = _batch_axes(mesh)
    bspec = jax.tree.map(
        lambda a: _fit((None, dp) + (None,) * (a.ndim - 2), a.shape, mesh),
        bshape)
    return model, opt, sshape, bshape, named(mesh, sspec), named(mesh, bspec)


def _serve_fsdp(pshape, mesh) -> bool:
    """Serving keeps weights TP-resident (no ZeRO-3 gather per step) unless
    the model is too large for model-parallel shards alone (~12 GB/chip)."""
    total = sum(np.prod(l.shape) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree_util.tree_leaves(pshape))
    model_n = dict(mesh.shape).get("model", 1)
    return (total / model_n) > 12e9


def serve_cell_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Specs for decode cells: (params, caches, token, step)."""
    model = Model(cfg)
    pshape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspec = param_specs(pshape, fsdp=_serve_fsdp(pshape, mesh))
    b = shape.global_batch
    cshape = jax.eval_shape(lambda: model.init_cache(b, shape.seq_len))
    cspec = cache_specs(cfg, cshape, mesh)
    dp = _batch_axes(mesh)
    tok = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    tspec = _fit((dp, None), tok.shape, mesh)
    return (model, pshape, cshape, tok,
            named(mesh, pspec), named(mesh, cspec),
            NamedSharding(mesh, tspec))


def prefill_cell_specs(cfg: ModelConfig, shape: ShapeSpec, mesh):
    model = Model(cfg)
    pshape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    pspec = param_specs(pshape, fsdp=_serve_fsdp(pshape, mesh))
    b = shape.global_batch
    s = shape.seq_len
    if cfg.max_target_positions:
        s = min(s, cfg.max_target_positions)
    batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    dp = _batch_axes(mesh)
    bspec = {"tokens": _fit((dp, None), (b, s), mesh)}
    if cfg.n_context_tokens or cfg.is_encdec:
        batch["context"] = jax.ShapeDtypeStruct(
            (b, cfg.n_context_tokens, cfg.d_model), jnp.float32)
        bspec["context"] = _fit((dp, None, None), batch["context"].shape,
                                mesh)
    return (model, pshape, batch, s,
            named(mesh, pspec), named(mesh, bspec))
