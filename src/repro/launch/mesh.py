"""Production mesh construction (pure function — importing this module never
touches jax device state). Mesh creation goes through repro.jax_compat so
the same code imports on old (no AxisType) and new JAX."""
from __future__ import annotations

from repro import jax_compat

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ("pod",) "data", "model" — pod is DCN-level data parallelism,
    data is intra-pod DP/FSDP, model is TP/EP/SP (DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax_compat.make_mesh((data, model), ("data", "model"))
