"""Production mesh construction (pure functions — importing this module
never touches jax device state). Mesh creation goes through
repro.jax_compat so the same code imports on old (no AxisType) and new
JAX. ``make_serve_mesh`` is the serve-cell entry point: it takes the
``--mesh data=4`` CLI spelling and builds a mesh over a *prefix* of the
local devices (unlike ``jax.make_mesh`` it does not require the axis
product to cover every device — a 2-way cell on a 4-device host is
legal)."""
from __future__ import annotations

from repro import jax_compat

__all__ = ["make_production_mesh", "make_local_mesh", "parse_mesh_spec",
           "make_serve_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips).

    Axes: ("pod",) "data", "model" — pod is DCN-level data parallelism,
    data is intra-pod DP/FSDP, model is TP/EP/SP (DESIGN.md §4).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax_compat.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests)."""
    return jax_compat.make_mesh((data, model), ("data", "model"))


def parse_mesh_spec(arg: str) -> dict[str, int]:
    """``"data=4"`` / ``"pod=2,data=2"`` -> an ordered ``{axis: size}``."""
    axes: dict[str, int] = {}
    for part in arg.split(","):
        name, _, size = part.partition("=")
        name = name.strip()
        try:
            n = int(size)
        except ValueError:
            n = 0
        if not name or n < 1:
            raise ValueError(
                f"mesh spec entries are axis=size (e.g. 'data=4'), "
                f"got {part!r} in {arg!r}")
        if name in axes:
            raise ValueError(f"duplicate mesh axis {name!r} in {arg!r}")
        axes[name] = n
    return axes


def make_serve_mesh(spec: str | dict[str, int]):
    """Mesh for a serve cell from a ``--mesh`` spec string or axis dict.

    Uses the first ``prod(sizes)`` local devices (axis order = spec
    order), so a cell smaller than the host is legal. On a CPU host,
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` fakes N
    devices — the tests/CI topology."""
    import jax
    import numpy as np

    axes = parse_mesh_spec(spec) if isinstance(spec, str) else dict(spec)
    n = 1
    for s in axes.values():
        n *= s
    devices = jax.devices()
    if n > len(devices):
        raise ValueError(
            f"mesh {axes} needs {n} devices but only {len(devices)} "
            f"are visible (on CPU, XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n} forces {n})")
    arr = np.asarray(devices[:n]).reshape(tuple(axes.values()))
    return jax.sharding.Mesh(arr, tuple(axes))
