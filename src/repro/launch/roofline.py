"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e-class chip):
  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_wire_bytes_per_device / link_bw

cost_analysis() reports the per-partition (per-device) module, so its
flops/bytes are already per-chip. Collective bytes come from parsing the
post-SPMD HLO text; per-op wire-byte factors are the standard ring
approximations (documented next to _COLL_FACTOR).
"""
from __future__ import annotations

import re

__all__ = ["HW", "collective_bytes", "roofline_terms", "model_flops"]

# TPU v5e-class constants (per chip) — from the task spec.
HW = {
    "peak_flops": 197e12,        # bf16
    "hbm_bw": 819e9,             # bytes/s
    "link_bw": 50e9,             # bytes/s per ICI link
}

# wire-bytes ≈ factor × parsed tensor bytes (ring-collective approximations;
# all-reduce moves ~2x the payload, gather/scatter/a2a/permute ~1x)
_COLL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_SHAPE_RX = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|s4|u4|pred)\[([0-9,]*)\]")


def _tensor_bytes(fragment: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RX.findall(fragment):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum wire bytes per collective kind from post-partitioning HLO."""
    out: dict[str, float] = {k: 0.0 for k in _COLL_FACTOR}
    out["count"] = 0
    for line in hlo_text.splitlines():
        for kind, factor in _COLL_FACTOR.items():
            marker = f" {kind}("
            if marker not in line:
                continue
            # result types are left of the opcode; reduce-scatter wire
            # traffic scales with its operand (the unscattered input)
            lhs, _, rhs = line.partition(marker)
            frag = rhs if kind == "reduce-scatter" else lhs
            out[kind] += factor * _tensor_bytes(frag)
            out["count"] += 1
            break
    out["total"] = sum(v for k, v in out.items()
                       if k in _COLL_FACTOR)
    return out


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict[str, float]:
    t_compute = flops_per_dev / HW["peak_flops"]
    t_memory = bytes_per_dev / HW["hbm_bw"]
    t_coll = coll_bytes_per_dev / HW["link_bw"]
    dom = max(("compute", t_compute), ("memory", t_memory),
              ("collective", t_coll), key=lambda kv: kv[1])
    total = max(t_compute, t_memory, t_coll)
    return {
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dom[0],
        "bound_s": total,
        "roofline_fraction": (t_compute / total) if total > 0 else 0.0,
    }


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) plus the
    attention/cache quadratic terms (which 6ND omits but are real useful
    work — decisive for decode against a 32k cache)."""
    n_params_active = _active_params(cfg)
    s = shape.seq_len
    if cfg.max_target_positions:
        s = min(s, cfg.max_target_positions)
    b = shape.global_batch
    pattern = tuple(cfg.block_pattern) * cfg.n_repeats + tuple(cfg.block_tail)
    n_attn = sum(k == "attn" for k in pattern)
    n_cross = sum(k == "cross" for k in pattern)
    h, hd, nc = cfg.n_heads, cfg.hd, cfg.n_context_tokens
    eff = min(s, cfg.local_window) if cfg.local_window else s

    if shape.kind == "train":
        tokens = s * b
        # causal scores+pv fwd = 2·B·S·eff·H·hd; train ≈ 3x fwd
        attn = n_attn * 6.0 * b * s * eff * h * hd \
            + n_cross * 12.0 * b * s * nc * h * hd
        return 6.0 * n_params_active * tokens + attn
    if shape.kind == "prefill":
        tokens = s * b
        attn = n_attn * 2.0 * b * s * eff * h * hd \
            + n_cross * 4.0 * b * s * nc * h * hd
        return 2.0 * n_params_active * tokens + attn
    # decode: one token against the cache
    attn = n_attn * 4.0 * b * eff * h * hd + n_cross * 4.0 * b * nc * h * hd
    return 2.0 * n_params_active * b + attn


def _active_params(cfg) -> float:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    attn = d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d
    mlp_dense = 3 * d * ff
    n = 0.0
    for i, kind in enumerate(tuple(cfg.block_pattern) * cfg.n_repeats
                             + tuple(cfg.block_tail)):
        if kind in ("attn", "cross"):
            n += attn
        elif kind == "rglru":
            n += 5 * d * d
        elif kind == "mlstm":
            n += 3 * d * (h * hd) + (h * hd) * d + 2 * d * h
        elif kind == "slstm":
            n += 9 * d * d
        if kind in ("attn", "cross", "rglru") and ff:
            if cfg.n_experts and kind == "attn":
                n += 3 * d * ff * (cfg.top_k + cfg.n_shared_experts)
            else:
                n += mlp_dense
    n += 2 * v * d if not cfg.tie_embeddings else v * d
    if cfg.is_encdec:
        n += cfg.encoder_layers * (attn + mlp_dense)
    return n
