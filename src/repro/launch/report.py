"""Render EXPERIMENTS.md tables from results/dryrun.json + results/calib.json.

Usage: PYTHONPATH=src python -m repro.launch.report [--update]
  --update rewrites the AUTOGEN blocks inside EXPERIMENTS.md in place.
"""
from __future__ import annotations

import argparse
import json
import re


def _fmt(v, digits=2):
    return f"{v:.{digits}e}" if isinstance(v, float) else str(v)


def dryrun_table(path="results/dryrun.json") -> str:
    recs = sorted(json.load(open(path)),
                  key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    rows = ["| arch | shape | mesh | status | compile s | arg GB/dev | "
            "temp GB/dev | HLO GF/dev | coll MB/dev | #coll |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r["status"] != "ok":
            note = r.get("reason", r.get("error", ""))[:60]
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"{r['status']}: {note} | | | | | | |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r['compile_s']:.0f} | "
            f"{r['arg_bytes_per_dev']/2**30:.2f} | "
            f"{r['temp_bytes_per_dev']/2**30:.2f} | "
            f"{r['hlo_flops_per_dev']/1e9:.1f} | "
            f"{r['collectives']['total']/2**20:.1f} | "
            f"{r['collectives']['count']:.0f} |")
    return "\n".join(rows)


def roofline_table(path="results/calib.json") -> str:
    recs = [r for r in json.load(open(path)) if r["status"] == "ok"]
    recs.sort(key=lambda r: (r["shape"], r["arch"]))
    rows = ["| arch | shape | compute s | memory s | collective s | "
            "dominant | bound s | roofline frac | useful (6ND+attn/HLO) |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt(r['t_compute_s'])} | "
            f"{_fmt(r['t_memory_s'])} | {_fmt(r['t_collective_s'])} | "
            f"**{r['dominant']}** | {_fmt(r['bound_s'])} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{min(r['useful_flops_ratio'], 9.99):.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--update", action="store_true")
    args = ap.parse_args()
    blocks = {"DRYRUN": dryrun_table(), "ROOFLINE": roofline_table()}
    if not args.update:
        for name, tbl in blocks.items():
            print(f"==== {name} ====\n{tbl}\n")
        return
    text = open("EXPERIMENTS.md").read()
    for name, tbl in blocks.items():
        text = re.sub(
            f"<!-- AUTOGEN:{name} -->.*?<!-- /AUTOGEN:{name} -->",
            f"<!-- AUTOGEN:{name} -->\n{tbl}\n<!-- /AUTOGEN:{name} -->",
            text, flags=re.S)
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
