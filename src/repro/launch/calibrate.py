import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

DOC = """Roofline calibration: exact per-layer HLO costs via depth-Δ.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the production (scanned) dry-run under-reports flops/bytes/
collective traffic by ~n_repeats. This tool lowers each cell at depth 1 and
depth 2 super-blocks with ALL scans unrolled, takes the per-super-block
delta, and extrapolates:

    corrected_X = X(1) + (n_repeats - 1) * (X(2) - X(1))

Known residual under-counts (documented in EXPERIMENTS.md §Roofline):
the sLSTM per-timestep scan and the mLSTM inter-chunk scan stay rolled
(unrolling 32k steps is not compilable); xlstm-125m train/prefill terms are
therefore lower bounds. Decode cells have no inner scans — exact.

Usage: python -m repro.launch.calibrate --out results/calib.json
"""

import argparse
import json
import time
import traceback

import jax

from repro import jax_compat
from repro.configs import get_config
from repro.configs.base import SHAPES
from repro.launch.dryrun import (DRYRUN_ARCHS, cell_skip_reason, lower_train,
                                 lower_decode, lower_prefill)
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import collective_bytes, model_flops, roofline_terms
from repro.models import attention, model


def _measure(cfg, shape, mesh):
    with jax_compat.set_mesh(mesh):
        if shape.kind == "train":
            lowered = lower_train(cfg, shape, mesh)
        elif shape.kind == "prefill":
            lowered = lower_prefill(cfg, shape, mesh)
        else:
            lowered = lower_decode(cfg, shape, mesh)
        compiled = lowered.compile()
    ca = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll["total"], "coll_detail": coll}


def calibrate_cell(arch: str, shape_name: str) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec = {"arch": cfg.name, "shape": shape_name, "mesh": "16x16"}
    if cell_skip_reason(cfg, shape):
        rec["status"] = "skipped"
        return rec
    mesh = make_production_mesh(multi_pod=False)
    pat, tail = len(cfg.block_pattern), len(cfg.block_tail)
    repeats = cfg.n_repeats
    t0 = time.time()
    try:
        model.SCAN_UNROLL = True
        attention.ATTN_UNROLL = True
        xs = []
        for r in (1, 2):
            cal = cfg.replace(n_layers=r * pat + tail, grad_accum=1)
            xs.append(_measure(cal, shape, mesh))
        d = {k: xs[1][k] - xs[0][k] for k in ("flops", "bytes", "coll")}
        accum = 1  # calibration at accum=1 covers the same total tokens
        corr = {k: xs[0][k] + (repeats - 1) * d[k]
                for k in ("flops", "bytes", "coll")}
        terms = roofline_terms(corr["flops"], corr["bytes"], corr["coll"])
        mf = model_flops(cfg, shape)
        rec.update({
            "status": "ok", "compile_s": round(time.time() - t0, 1),
            "per_layer": {k: d[k] / pat for k in d},
            "once": {k: xs[0][k] - d[k] for k in d},
            "flops_per_dev": corr["flops"], "bytes_per_dev": corr["bytes"],
            "coll_bytes_per_dev": corr["coll"],
            "model_flops_global": mf,
            "useful_flops_ratio": mf / (corr["flops"] * 256)
            if corr["flops"] else 0.0,
            **terms,
        })
        del accum
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-1500:]
    finally:
        model.SCAN_UNROLL = 1
        attention.ATTN_UNROLL = 1
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/calib.json")
    args = ap.parse_args()
    archs = DRYRUN_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    existing = {}
    if os.path.exists(args.out):
        for r in json.load(open(args.out)):
            existing[(r["arch"], r["shape"])] = r
    for arch in archs:
        for shape in shapes:
            key = (get_config(arch).name, shape)
            if key in existing and existing[key]["status"] in ("ok",
                                                               "skipped"):
                print(f"[cached ] {key}")
                continue
            rec = calibrate_cell(arch, shape)
            existing[key] = rec
            msg = (f"dom={rec.get('dominant')} "
                   f"frac={rec.get('roofline_fraction', 0):.3f}"
                   if rec["status"] == "ok"
                   else rec.get("error", "")[:90])
            print(f"[{rec['status']:7s}] {key} {msg}", flush=True)
            with open(args.out, "w") as f:
                json.dump(list(existing.values()), f, indent=1)
    fails = sum(r["status"] == "fail" for r in existing.values())
    print(f"done; {fails} failures -> {args.out}")
    return 1 if fails else 0


if __name__ == "__main__":
    raise SystemExit(main())
