"""llama1-7b — the paper's own evaluation model (Sec. 5.1/5.4)."""
from repro.configs.base import ModelConfig
from repro.quant import QuantConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama1-7b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
        d_ff=11008, vocab=32000,
        tie_embeddings=False,
        quant=QuantConfig(mode="none", w_bits=4, a_bits=8, group=128),
    )
