"""Model / run configuration dataclasses shared by all architectures."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.quant.qlinear import QuantConfig

__all__ = ["ModelConfig", "ShapeSpec", "SHAPES", "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | hybrid | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 → d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    expert_capacity_factor: float = 1.25

    # --- block pattern (super-block scanned n_layers/len(pattern) times) ---
    block_pattern: tuple[str, ...] = ("attn",)   # attn|cross|rglru|mlstm|slstm
    block_tail: tuple[str, ...] = ()  # remainder layers applied after scan
    mlp_after: tuple[int, ...] | None = None   # pattern idxs with MLP (None=all)
    local_window: int = 0            # 0 → global attention

    # --- modality frontends (stubs per spec) ---
    n_context_tokens: int = 0        # vision patches / audio frames fed as
                                     # precomputed embeddings via input_specs
    encoder_layers: int = 0          # whisper encoder depth (enc-dec)
    max_target_positions: int = 0    # whisper decoder cap (448)

    # --- flags ---
    qk_norm: bool = False
    rope_2d: bool = False            # chatglm-style partial rotary
    tie_embeddings: bool = True
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6

    # --- quantization (the paper's technique; serve path) ---
    quant: QuantConfig = QuantConfig()
    quant_attention: bool = False    # dynamic int8 attention GEMMs (Sec. 5.7)
    kv_cache_bits: int = 16          # 8 → int8 KV cache + stored scales
    paged_kernel: bool = False       # paged decode walks live pages via the
                                     # Pallas kernel (kernels/paged_attention)
                                     # instead of gathering the full extent

    # --- training substrate knobs ---
    dtype: Any = jnp.bfloat16
    remat: str = "block"             # none | block
    grad_accum: int = 1
    seq_shard: bool = False          # Megatron-SP activations between blocks
    opt_state_dtype: Any = jnp.float32
    factored_second_moment: bool = False   # Adafactor-style v (huge models)
    compress_pod_grads: bool = False       # int8+error-feedback DCN psum

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_repeats(self) -> int:
        body = self.n_layers - len(self.block_tail)
        assert body % len(self.block_pattern) == 0, \
            (self.name, self.n_layers, self.block_pattern, self.block_tail)
        return body // len(self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Can serve 500k context (recurrent state or local-window attn)."""
        kinds = set(self.block_pattern)
        if kinds & {"rglru", "mlstm", "slstm"}:
            return self.local_window > 0 or "attn" not in kinds or True
        return self.local_window > 0

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test-sized variant of the same family (one super-block repeat
    or two, tiny widths, few experts, small vocab)."""
    pat = cfg.block_pattern
    heads = min(cfg.n_heads, 4)
    kv = max(1, min(cfg.n_kv_heads, heads))
    while heads % kv:
        kv -= 1
    return cfg.replace(
        n_layers=len(pat) * min(2, cfg.n_repeats) + len(cfg.block_tail),
        d_model=128, n_heads=heads, n_kv_heads=kv, head_dim=32,
        d_ff=256 if cfg.d_ff else 0, vocab=512,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        n_context_tokens=64 if cfg.n_context_tokens else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        max_target_positions=64 if cfg.max_target_positions else 0,
        local_window=min(cfg.local_window, 64) if cfg.local_window else 0,
        quant=cfg.quant.with_(group=64),
        grad_accum=1, remat="none",
    )
