"""Architecture config registry: one module per assigned arch (+ paper's).

``get_config(name)`` returns the full-size ModelConfig;
``get_reduced(name)`` the smoke-test-sized variant of the same family.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeSpec, SHAPES, reduced  # noqa: F401

ARCHS = [
    "moonshot_v1_16b_a3b",
    "llama4_maverick_400b_a17b",
    "llama_3_2_vision_90b",
    "recurrentgemma_9b",
    "smollm_135m",
    "mistral_nemo_12b",
    "qwen3_14b",
    "chatglm3_6b",
    "xlstm_125m",
    "whisper_tiny",
    "llama1_7b",          # the paper's own evaluation model
]

_ALIAS = {a.replace("_", "-"): a for a in ARCHS}


def canonical(name: str) -> str:
    key = name.replace("-", "_").replace(".", "_")
    if key in ARCHS:
        return key
    if name in _ALIAS:
        return _ALIAS[name]
    raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")


def get_config(name: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(name)}")
    return mod.config()


def get_reduced(name: str) -> ModelConfig:
    return reduced(get_config(name))
