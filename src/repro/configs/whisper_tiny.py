"""whisper-tiny [audio] — enc-dec; conv frontend is a stub (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="audio",
        n_layers=8, d_model=384, n_heads=6, n_kv_heads=6,
        d_ff=1536, vocab=51865,
        block_pattern=("attn", "cross"),   # 8 pattern-layers = 4 dec layers
        mlp_after=(1,),                    # whisper layer: self -> cross -> mlp
        encoder_layers=4,
        n_context_tokens=1500,
        max_target_positions=448,
        tie_embeddings=True,
        grad_accum=4,
    )
