"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
early-fusion frontend out of scope (text backbone per assignment).
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=8192, vocab=202048,
        n_experts=128, top_k=1, n_shared_experts=1,
        block_pattern=("attn",),
        grad_accum=16,
        factored_second_moment=True,
        opt_state_dtype="bfloat16",   # + factored 2nd moment (Adafactor):
                                      # ~790B params cannot hold full f32
                                      # moments in 4 TB of pod HBM
    )
