"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks, no FFN (d_ff=0).
[arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m", family="ssm",
        n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304, head_dim=192,
        block_pattern=("mlstm", "slstm"),
        grad_accum=4,
    )
