"""mistral-nemo-12b [dense] — 128k ctx. [hf:mistralai/Mistral-Nemo-Base-2407; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-nemo-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=14336, vocab=131072, head_dim=128,
        tie_embeddings=False, rope_theta=1e6,
        grad_accum=8,
    )
