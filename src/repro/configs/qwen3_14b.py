"""qwen3-14b [dense] — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-14b", family="dense",
        n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=17408, vocab=151936, head_dim=128,
        qk_norm=True, tie_embeddings=False, rope_theta=1e6,
        grad_accum=8,
    )
