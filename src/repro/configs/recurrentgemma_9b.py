"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 1 attn : 2 rglru.
[arXiv:2402.19427; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
        d_ff=12288, vocab=256000,
        block_pattern=("rglru", "rglru", "attn"),
        block_tail=("rglru", "rglru"),
        local_window=2048,
        grad_accum=8,
    )
