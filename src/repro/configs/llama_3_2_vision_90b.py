"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer;
vision frontend is a stub (input_specs provides patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b", family="vlm",
        n_layers=100, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=28672, vocab=128256,
        block_pattern=("attn", "attn", "attn", "attn", "cross"),
        n_context_tokens=1024,
        tie_embeddings=False,
        seq_shard=True,               # Megatron-SP: d=8192 x 100L activations
        grad_accum=16,
    )
