"""Model assembly: scan-over-super-blocks decoder (all 10 families), with
train / prefill / decode entry points and layer-stacked KV/recurrent caches.

A config's ``block_pattern`` defines one super-block; the super-block is
scanned ``n_repeats`` times (keeps HLO size O(pattern), essential for
512-device compiles). Pattern elements:
  attn   — GQA self-attention (+ MLP if d_ff > 0)
  cross  — cross-attention to ``context`` embeddings (+ MLP)
  rglru  — RG-LRU recurrent block (+ MLP)
  mlstm / slstm — xLSTM blocks (self-contained, no MLP)
Encoder-decoder (whisper): a separate non-causal encoder stack feeds
``context``.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import attention as A
from repro.models import blocks as B

Params = dict[str, Any]

# Calibration knob (launch/calibrate.py): XLA's HloCostAnalysis counts a
# while-loop body ONCE regardless of trip count, so roofline calibration
# lowers shallow model variants with scans fully unrolled. 1 = rolled.
SCAN_UNROLL: int | bool = 1


def _scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=SCAN_UNROLL)


def _init_superblock(key, cfg: ModelConfig, pattern) -> Params:
    p = {}
    keys = jax.random.split(key, 2 * len(pattern))
    gelu = cfg.family == "audio"
    for i, kind in enumerate(pattern):
        k1, k2 = keys[2 * i], keys[2 * i + 1]
        if kind == "attn":
            p[f"b{i}"] = A.init_attn(k1, cfg)
        elif kind == "cross":
            p[f"b{i}"] = A.init_attn(k1, cfg, cross=True)
        elif kind == "rglru":
            p[f"b{i}"] = B.init_rglru(k1, cfg)
        elif kind == "mlstm":
            p[f"b{i}"] = B.init_mlstm(k1, cfg)
        elif kind == "slstm":
            p[f"b{i}"] = B.init_slstm(k1, cfg)
        else:
            raise ValueError(kind)
        wants_mlp = (kind in ("attn", "cross", "rglru") and cfg.d_ff
                     and (cfg.mlp_after is None or i in cfg.mlp_after))
        if wants_mlp:
            if cfg.family == "moe" and kind == "attn":
                p[f"m{i}"] = B.init_moe(k2, cfg)
            else:
                p[f"m{i}"] = B.init_mlp(k2, cfg, gelu=gelu)
    return p


def _apply_superblock(bp: Params, x, cfg: ModelConfig, pattern, *,
                      positions, caches=None, step=None, causal=True,
                      context=None, prefill=False):
    """One super-block pass; returns (x, new_caches or None)."""
    new_caches = {} if caches is not None else None
    sp = "seq_sp" if cfg.seq_shard else None
    for i, kind in enumerate(pattern):
        cache_i = caches.get(f"c{i}") if caches is not None else None
        if kind in ("attn", "cross"):
            window = cfg.local_window if kind == "attn" else 0
            y, nc = A.apply_attn(
                bp[f"b{i}"], x, cfg, positions=positions, cache=cache_i,
                step=step, causal=causal and kind == "attn", window=window,
                context=context if kind == "cross" else None,
                prefill=prefill)
        elif kind == "rglru":
            y, nc = B.apply_rglru(bp[f"b{i}"], x, cfg, cache=cache_i,
                                  prefill=prefill)
        elif kind == "mlstm":
            y, nc = B.apply_mlstm(bp[f"b{i}"], x, cfg, cache=cache_i,
                                  prefill=prefill)
        elif kind == "slstm":
            y, nc = B.apply_slstm(bp[f"b{i}"], x, cfg, cache=cache_i,
                                  prefill=prefill)
        else:
            raise ValueError(kind)
        x = shard(x + y, "batch", sp, None)
        if f"m{i}" in bp:
            if cfg.family == "moe" and kind == "attn":
                x = x + B.apply_moe(bp[f"m{i}"], x, cfg)
            else:
                x = x + B.apply_mlp(bp[f"m{i}"], x, cfg)
            x = shard(x, "batch", sp, None)
        if new_caches is not None:
            new_caches[f"c{i}"] = nc if nc is not None else cache_i
    return x, new_caches


def _apply_superblock_paged(bp: Params, x, cfg: ModelConfig, pattern, *,
                            pool, mode: str, **attn_kw):
    """One super-block pass against a page pool (continuous-batching serve).

    ``mode`` is "prefill", "prefill_batched" or "decode"; ``attn_kw``
    forwards to the paged attention entry point. Residual/MLP structure
    mirrors :func:`_apply_superblock` exactly — only the KV storage
    differs."""
    new_pool = {}
    sp = "seq_sp" if cfg.seq_shard else None
    paged_fns = {"prefill": A.apply_attn_paged_prefill,
                 "prefill_batched": A.apply_attn_paged_prefill_batched,
                 "decode": A.apply_attn_paged_decode}
    for i, kind in enumerate(pattern):
        if kind != "attn":
            raise NotImplementedError(
                f"paged serving supports self-attention blocks only, got "
                f"{kind!r} in pattern {pattern} (recurrent/cross blocks "
                f"keep per-slot dense state; see repro.serve)")
        fn = paged_fns[mode]
        y, npl = fn(bp[f"b{i}"], x, cfg, pool=pool[f"c{i}"], **attn_kw)
        x = shard(x + y, "batch", sp, None)
        if f"m{i}" in bp:
            if cfg.family == "moe" and kind == "attn":
                x = x + B.apply_moe(bp[f"m{i}"], x, cfg)
            else:
                x = x + B.apply_mlp(bp[f"m{i}"], x, cfg)
            x = shard(x, "batch", sp, None)
        new_pool[f"c{i}"] = npl
    return x, new_pool


class Model:
    """Functional model: init / loss / prefill / decode_step."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = cfg.block_pattern

    # ---- init --------------------------------------------------------------
    def init(self, key: jax.Array) -> Params:
        cfg = self.cfg
        k_embed, k_blocks, k_enc, k_head = jax.random.split(key, 4)
        embed = (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                   jnp.float32) * 0.02).astype(cfg.dtype)
        bkeys = jax.random.split(k_blocks, cfg.n_repeats)
        blocks = jax.vmap(
            lambda k: _init_superblock(k, cfg, self.pattern))(bkeys)
        params: Params = {
            "embed": embed,
            "blocks": blocks,
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = (jax.random.normal(
                k_head, (cfg.vocab, cfg.d_model), jnp.float32) * 0.02
            ).astype(cfg.dtype)
        if cfg.block_tail:
            params["tail"] = _init_superblock(
                jax.random.fold_in(k_blocks, 7), cfg, cfg.block_tail)
        if cfg.is_encdec:
            ecfg = cfg.replace(mlp_after=None)
            ekeys = jax.random.split(k_enc, cfg.encoder_layers)
            params["encoder"] = jax.vmap(
                lambda k: _init_superblock(k, ecfg, ("attn",)))(ekeys)
            params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
        return params

    # ---- serve-path plan warmup -------------------------------------------
    def precompile_plans(self, params: Params) -> dict:
        """Build every PTQ linear's engine ExecutionPlan ahead of serving.

        The offline half of the paper's offline/online split: walks the
        params pytree (including scan-stacked block weights) and warms the
        **process-level** plan cache — the only cache the qlinear hot-path
        callbacks consult (swap it via ``plancache.set_default_cache``) —
        so decode only ever pays ``run``. No-op (empty stats) unless this
        model's registered backend declares an offline plan half
        (``needs_plan`` capability, core/backend.py).
        """
        q = self.cfg.quant
        if q.mode != "ptq":
            return {"layers": 0, "plans": 0, "built": 0}
        from repro.core.backend import get_backend
        if not get_backend(q).needs_plan:
            return {"layers": 0, "plans": 0, "built": 0}
        from repro.core import plancache
        return plancache.precompile(params, q)

    def attach_device_plans(self, params: Params, *, mesh=None,
                            specs=None) -> Params:
        """Embed compiled DevicePlans into the params for pure-JAX serving.

        The device-resident half of the offline split: every PTQ layer
        gains a ``"dplan"`` pytree (stacked along scan-stacked leading
        axes) that ``lax.scan`` slices alongside the weights, so
        device-resident planned backends (``engine_jit``,
        ``engine_pallas``, any custom one declaring ``device_resident`` +
        ``needs_plan``) execute with zero host callbacks even though block
        weights are tracers inside the scan. With ``mesh=`` the plan
        leaves are placed under ``specs`` (``PartitionSpec``s — see
        ``repro.core.backend.shard_device_plan``) for multi-device
        serving. No-op unless the configured backend has both
        capabilities.
        """
        q = self.cfg.quant
        if q.mode != "ptq":
            return params
        from repro.core.backend import get_backend
        b = get_backend(q)
        if not (b.needs_plan and b.device_resident):
            return params
        from repro.core import plancache
        return plancache.attach_device_plans(params, q, mesh=mesh,
                                             specs=specs)

    # ---- shared ------------------------------------------------------------
    def _embed_tokens(self, params, tokens):
        x = jnp.take(params["embed"], tokens, axis=0).astype(self.cfg.dtype)
        return shard(x, "batch", None, None)

    def _logits(self, params, x):
        x = A.rms_norm(x, params["final_norm"], self.cfg.norm_eps)
        table = params.get("unembed", params["embed"])
        logits = jax.lax.dot_general(
            x, table.astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())))
        return shard(logits.astype(jnp.float32), "batch", None, "vocab")

    def _encode(self, params, frames):
        cfg = self.cfg.replace(mlp_after=None)
        x = shard(frames.astype(cfg.dtype), "batch", None, None)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, bp):
            y, _ = _apply_superblock(bp, carry, cfg, ("attn",),
                                     positions=pos, causal=False)
            return y, None
        x, _ = _scan(body, x, params["encoder"])
        return A.rms_norm(x, params["enc_norm"], cfg.norm_eps)

    def _context(self, params, batch):
        if self.cfg.is_encdec:
            return self._encode(params, batch["context"])
        if self.cfg.n_context_tokens:
            return shard(batch["context"].astype(self.cfg.dtype),
                         "batch", None, None)
        return None

    # ---- train -------------------------------------------------------------
    def loss(self, params: Params, batch: dict) -> jnp.ndarray:
        cfg = self.cfg
        tokens, labels = batch["tokens"], batch["labels"]
        context = self._context(params, batch)
        x = self._embed_tokens(params, tokens)
        pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])

        def body(carry, bp):
            y, _ = _apply_superblock(bp, carry, cfg, self.pattern,
                                     positions=pos, context=context)
            return y, None
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = _scan(body, x, params["blocks"])
        if cfg.block_tail:
            x, _ = _apply_superblock(params["tail"], x, cfg, cfg.block_tail,
                                     positions=pos, context=context)
        logits = self._logits(params, x)
        # fused CE: no (B,S,V) log-softmax materialisation; the one-hot dot
        # reduces over the vocab-sharded axis in place.
        lse = jax.nn.logsumexp(logits, axis=-1)
        onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
        ll = jnp.einsum("...v,...v->...", logits, onehot)
        return (lse - ll).mean()

    # ---- serve -------------------------------------------------------------
    @staticmethod
    def _shard_cache_batch(tree, axis: int):
        """Batch-dim sharding constraint on every cache leaf (no-op without
        an ambient mesh). Caches are created inside the prefill jit; the
        constraint keeps them data-sharded from the first write, so the
        mesh serve cell never materialises a replicated KV cache and the
        donated decode buffers keep a stable sharding across steps."""
        def one(a):
            axes: list[str | None] = [None] * a.ndim
            axes[axis] = "batch"
            return shard(a, *axes)
        return jax.tree.map(one, tree)

    def init_cache(self, batch: int, max_len: int):
        cfg = self.cfg
        if cfg.max_target_positions:
            max_len = min(max_len, cfg.max_target_positions)

        def one(kind):
            if kind == "attn":
                return A.init_attn_cache(cfg, batch, max_len,
                                         cfg.local_window)
            if kind == "cross":
                return A.init_attn_cache(cfg, batch,
                                         cfg.n_context_tokens or 1,
                                         cross=True)
            if kind == "rglru":
                return B.cache_rglru(cfg, batch)
            if kind == "mlstm":
                return B.cache_mlstm(cfg, batch)
            if kind == "slstm":
                return B.cache_slstm(cfg, batch)
            raise ValueError(kind)

        def stack(tree):
            return jax.tree.map(
                lambda a: jnp.zeros((cfg.n_repeats,) + a.shape, a.dtype),
                tree)
        caches = {"body": self._shard_cache_batch(
            {f"c{i}": stack(one(kind))
             for i, kind in enumerate(self.pattern)}, axis=1)}
        if cfg.block_tail:
            caches["tail"] = self._shard_cache_batch(
                {f"c{i}": one(kind)
                 for i, kind in enumerate(cfg.block_tail)}, axis=0)
        return caches

    # ---- paged serve (continuous batching, repro.serve) --------------------
    def supports_paged(self) -> str | None:
        """None when the paged serve path covers this config, else why not."""
        cfg = self.cfg
        if any(k != "attn" for k in self.pattern):
            return f"block pattern {self.pattern} has non-attn blocks"
        if cfg.block_tail:
            return f"block_tail {cfg.block_tail} is not paged"
        if cfg.local_window:
            return "local-window (rolling) caches are not paged"
        if cfg.n_context_tokens or cfg.is_encdec:
            return "cross-attention context caches are not paged"
        return None

    def init_page_pool(self, n_pages: int, page_size: int):
        """Layer-stacked paged KV pool: leaves (n_repeats, n_pages,
        page_size, KV, D) (+ scale leaves under KV8). No batch axis — slots
        exist only in the page table the serve engine packs per step."""
        reason = self.supports_paged()
        if reason is not None:
            raise NotImplementedError(f"paged KV pool: {reason}")
        cfg = self.cfg
        one = A.init_attn_page_pool(cfg, n_pages, page_size)
        stacked = jax.tree.map(
            lambda a: jnp.zeros((cfg.n_repeats,) + a.shape, a.dtype), one)
        return {"body": {f"c{i}": stacked
                         for i in range(len(self.pattern))}}

    def prefill_paged(self, params: Params, tokens, pool, *,
                      prefix_page_ids, write_page_ids, write_offs,
                      write_from: int = 0):
        """Suffix prefill for one request through the page pool.

        ``tokens`` (1, Ls) is the prompt suffix after the shared range
        (``len(prefix_page_ids) * page_size`` positions, gathered from the
        pool). Returns (last-position logits, new pool). Static shapes:
        retraces per (Ls, n_prefix_pages, write_from) combination."""
        cfg = self.cfg

        def body(carry, xs):
            bp, pl = xs
            y, npl = _apply_superblock_paged(
                bp, carry, cfg, self.pattern, pool=pl, mode="prefill",
                prefix_page_ids=prefix_page_ids,
                write_page_ids=write_page_ids, write_offs=write_offs,
                write_from=write_from)
            return y, npl
        x = self._embed_tokens(params, tokens)
        x, new_body = _scan(body, x, (params["blocks"], pool["body"]))
        logits = self._logits(params, x[:, -1:])
        return logits, {"body": new_body}

    def prefill_paged_batched(self, params: Params, tokens, pool, *,
                              prefix_page_ids, prefix_lens, suffix_lens,
                              write_page_ids, write_offs, write_pos):
        """Bucket-padded batched prefill: N requests' suffixes in one call.

        ``tokens`` (B, Lb) holds each row's prompt suffix left-aligned and
        zero-padded to the bucket length; see
        :func:`repro.models.attention.apply_attn_paged_prefill_batched`
        for the index-array contract. Returns (per-row last-real-position
        logits (B, 1, V), new pool). Static per (B, Lb, PPb) bucket."""
        cfg = self.cfg

        def body(carry, xs):
            bp, pl = xs
            y, npl = _apply_superblock_paged(
                bp, carry, cfg, self.pattern, pool=pl,
                mode="prefill_batched",
                prefix_page_ids=prefix_page_ids, prefix_lens=prefix_lens,
                suffix_lens=suffix_lens, write_page_ids=write_page_ids,
                write_offs=write_offs, write_pos=write_pos)
            return y, npl
        x = self._embed_tokens(params, tokens)
        x, new_body = _scan(body, x, (params["blocks"], pool["body"]))
        last = jnp.take_along_axis(
            x, (suffix_lens - 1)[:, None, None].astype(jnp.int32), axis=1)
        logits = self._logits(params, last)
        return logits, {"body": new_body}

    def decode_step_paged(self, params: Params, pool, tokens, page_indices,
                          steps, kernel: bool | None = None):
        """One packed decode step over every slot. tokens (B, 1) int32;
        page_indices (B, P) int32; steps (B,) int32 per-slot positions.
        Returns (logits (B, 1, V), new pool). One fixed shape — zero
        retraces as requests come and go. ``kernel`` (static under jit)
        selects the Pallas live-page attention path; None defers to
        ``cfg.paged_kernel``."""
        cfg = self.cfg

        def body(carry, xs):
            bp, pl = xs
            y, npl = _apply_superblock_paged(
                bp, carry, cfg, self.pattern, pool=pl, mode="decode",
                page_indices=page_indices, steps=steps, kernel=kernel)
            return y, npl
        x = self._embed_tokens(params, tokens)
        x, new_body = _scan(body, x, (params["blocks"], pool["body"]))
        logits = self._logits(params, x)
        return logits, {"body": new_body}

    def prefill(self, params: Params, batch: dict, max_len: int):
        """Process the prompt, fill caches; returns (last-pos logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        context = self._context(params, batch)
        caches = self.init_cache(b, max_len)
        x = self._embed_tokens(params, tokens)
        pos = jnp.broadcast_to(jnp.arange(s), (b, s))

        def body(carry, xs):
            bp, cache_slice = xs
            y, nc = _apply_superblock(
                bp, carry, cfg, self.pattern, positions=pos,
                caches=cache_slice, context=context, prefill=True)
            return y, nc
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        x, new_body = _scan(body, x, (params["blocks"], caches["body"]))
        out = {"body": new_body}
        if cfg.block_tail:
            x, out["tail"] = _apply_superblock(
                params["tail"], x, cfg, cfg.block_tail, positions=pos,
                caches=caches["tail"], context=context, prefill=True)
        logits = self._logits(params, x[:, -1:])
        return logits, out

    def decode_step(self, params: Params, caches, token, step):
        """One decode step. token (B, 1) int32; step scalar int32 position."""
        cfg = self.cfg
        b = token.shape[0]
        # context K/V live in the cross caches after prefill; only the
        # stub-embedding shape is needed to signal cross blocks.
        context = (jnp.zeros((b, cfg.n_context_tokens, cfg.d_model),
                             cfg.dtype)
                   if (cfg.n_context_tokens or cfg.is_encdec) else None)
        if cfg.is_encdec and context is None:
            context = jnp.zeros((b, 1, cfg.d_model), cfg.dtype)
        x = self._embed_tokens(params, token)
        pos = jnp.broadcast_to(step, (b, 1)).astype(jnp.int32)

        def body(carry, xs):
            bp, cache_slice = xs
            y, nc = _apply_superblock(bp, carry, cfg, self.pattern,
                                      positions=pos, caches=cache_slice,
                                      step=step, context=context)
            return y, nc
        x, new_body = _scan(body, x, (params["blocks"], caches["body"]))
        out = {"body": new_body}
        if cfg.block_tail:
            x, out["tail"] = _apply_superblock(
                params["tail"], x, cfg, cfg.block_tail, positions=pos,
                caches=caches["tail"], step=step, context=context)
        logits = self._logits(params, x)
        return logits, out
