"""Non-attention block types: MLP (swiglu/gelu), MoE (expert-parallel
ragged dispatch), RG-LRU recurrent block, mLSTM/sLSTM blocks.

Every block type exposes:
  init_<t>(key, cfg) -> params
  apply_<t>(params, x, cfg, *, cache, step, ...) -> (y, new_cache)
  cache_<t>(cfg, batch, max_len) -> cache pytree (or None)
Residual connections live in model.py; blocks are pre-norm bodies.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jax_compat
from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.attention import rms_norm
from repro.quant import linear_init, linear_apply

# --------------------------------------------------------------------------
# Dense MLP
# --------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, gelu: bool = False):
    ks = jax.random.split(key, 3)
    p = {"norm": jnp.ones((cfg.d_model,), jnp.float32),
         "up": linear_init(ks[0], cfg.d_model, cfg.d_ff, cfg.quant, cfg.dtype),
         "down": linear_init(ks[1], cfg.d_ff, cfg.d_model, cfg.quant, cfg.dtype)}
    if not gelu:
        p["gate"] = linear_init(ks[2], cfg.d_model, cfg.d_ff, cfg.quant,
                                cfg.dtype)
    return p


def apply_mlp(params, x, cfg: ModelConfig):
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    up = linear_apply(params["up"], xn, cfg.quant)
    up = shard(up, "batch", None, "ffn")
    if "gate" in params:
        gate = linear_apply(params["gate"], xn, cfg.quant)
        gate = shard(gate, "batch", None, "ffn")
        h = jax.nn.silu(gate) * up
    else:
        h = jax.nn.gelu(up)
    return linear_apply(params["down"], h, cfg.quant).astype(x.dtype)


# --------------------------------------------------------------------------
# MoE with expert-parallel ragged dispatch (DESIGN.md §4)
# --------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    lim = 1.0 / math.sqrt(d)
    p = {
        "norm": jnp.ones((d,), jnp.float32),
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * lim,
        "w_gate": jax.random.normal(ks[1], (e, d, f), jnp.float32) * lim,
        "w_up": jax.random.normal(ks[2], (e, d, f), jnp.float32) * lim,
        "w_down": jax.random.normal(ks[3], (e, f, d), jnp.float32)
        * (1.0 / math.sqrt(f)),
    }
    p = {k: (v.astype(cfg.dtype) if k != "norm" else v) for k, v in p.items()}
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg)
    return p


def _moe_local(x2, gates, eids, w_gate, w_up, w_down, n_local: int,
               capacity: int):
    """Expert computation on one shard's local tokens.

    x2 (N, D); gates/eids (N, K) *local* expert ids in [0, n_local) or
    n_local for not-owned. Sorted-capacity ragged_dot dispatch.
    """
    n, k = eids.shape
    d = x2.shape[-1]
    flat_e = eids.reshape(-1)
    flat_g = gates.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(n), k)
    order = jnp.argsort(flat_e, stable=True)            # owned groups first
    keep = order[:capacity]
    e_kept = flat_e[keep]
    tok_kept = flat_tok[keep]
    g_kept = jnp.where(e_kept < n_local, flat_g[keep], 0.0)
    xs = x2[tok_kept]                                   # (C, D)
    group_sizes = jnp.bincount(jnp.minimum(e_kept, n_local),
                               length=n_local + 1)[:n_local].astype(jnp.int32)
    # pad rhs with nothing: rows beyond sum(group_sizes) fall into an
    # implicit tail we mask via g_kept == 0.
    # keep the expert math in the working dtype end-to-end: the MXU still
    # accumulates in f32 internally, but bf16 op outputs keep the forward
    # psum AND the backward cotangent psums/all-reduces at half the wire
    # bytes (§Perf HC3 — f32 cotangents were the dominant collective).
    acc = x2.dtype
    gate_h = jax.lax.ragged_dot(xs, w_gate, group_sizes,
                                preferred_element_type=acc)
    up_h = jax.lax.ragged_dot(xs, w_up, group_sizes,
                              preferred_element_type=acc)
    h = jax.nn.silu(gate_h) * up_h
    out = jax.lax.ragged_dot(h.astype(w_down.dtype), w_down, group_sizes,
                             preferred_element_type=acc)
    y = jnp.zeros((n, d), x2.dtype)
    y = y.at[tok_kept].add((out * g_kept[:, None].astype(out.dtype))
                           .astype(x2.dtype))
    return y


def apply_moe(params, x, cfg: ModelConfig):
    """Top-k MoE; experts sharded over the "model" axis via shard_map when a
    mesh is ambient, single-shard fallback otherwise."""
    b, s, d = x.shape
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    logits = (xn.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates, eids = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    mesh = jax_compat.get_abstract_mesh()
    ep = (mesh is not None and "model" in mesh.axis_names
          and cfg.n_experts % mesh.shape["model"] == 0)

    if not ep:
        x2 = xn.reshape(b * s, d)
        cap = int(b * s * cfg.top_k)
        y = _moe_local(x2, gates.reshape(b * s, -1).astype(x.dtype),
                       eids.reshape(b * s, -1), params["w_gate"],
                       params["w_up"], params["w_down"], cfg.n_experts, cap)
        y = y.reshape(b, s, d)
    else:
        n_shards = mesh.shape["model"]
        n_local = cfg.n_experts // n_shards
        dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        xspec = P(dp_axes, None, None)

        def ep_fn(xn_l, gates_l, eids_l, wg, wu, wd):
            idx = jax.lax.axis_index("model")
            bl, sl = xn_l.shape[0], xn_l.shape[1]
            n_tok = bl * sl
            x2 = xn_l.reshape(n_tok, d)
            e2 = eids_l.reshape(n_tok, cfg.top_k)
            g2 = gates_l.reshape(n_tok, cfg.top_k)
            owned = (e2 // n_local) == idx
            lid = jnp.where(owned, e2 % n_local, n_local)
            cap = int(n_tok * cfg.top_k * cfg.expert_capacity_factor
                      / n_shards) + 1
            y = _moe_local(x2, g2.astype(xn_l.dtype), lid, wg[0], wu[0], wd[0],
                           n_local, cap)
            y = jax.lax.psum(y.astype(xn_l.dtype), "model")
            return y.reshape(bl, sl, d)

        wspec = P(None, "model", None, None)
        y = jax_compat.shard_map(
            ep_fn, mesh=mesh,
            in_specs=(xspec, xspec, xspec, wspec, wspec, wspec),
            out_specs=xspec, check_vma=False,
        )(xn, gates.astype(x.dtype), eids,
          params["w_gate"][None], params["w_up"][None], params["w_down"][None])

    if "shared" in params:
        y = y + apply_mlp(params["shared"], x, cfg)
    return y.astype(x.dtype)


# --------------------------------------------------------------------------
# RG-LRU recurrent block (recurrentgemma; arXiv:2402.19427)
# --------------------------------------------------------------------------

RGLRU_C = 8.0


def init_rglru(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    u = jax.random.uniform(ks[4], (d,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / RGLRU_C))   # softplus^-1(-log u / c)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_x": linear_init(ks[0], d, d, cfg.quant, cfg.dtype),
        "w_gate": linear_init(ks[1], d, d, cfg.quant, cfg.dtype),
        "w_r": linear_init(ks[2], d, d, cfg.quant, cfg.dtype),
        "w_i": linear_init(ks[3], d, d, cfg.quant, cfg.dtype),
        "lam": lam,
        "w_out": linear_init(ks[5], d, d, cfg.quant, cfg.dtype),
    }


def cache_rglru(cfg: ModelConfig, batch: int):
    return {"h": jnp.zeros((batch, cfg.d_model), jnp.float32)}


def apply_rglru(params, x, cfg: ModelConfig, *, cache=None, prefill=False):
    """Griffin-style recurrent block (temporal conv omitted; DESIGN.md §8).

    Returns (y, new_cache)."""
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    xi = linear_apply(params["w_x"], xn, cfg.quant)
    gate = jax.nn.gelu(linear_apply(params["w_gate"], xn, cfg.quant))
    r = jax.nn.sigmoid(linear_apply(params["w_r"], xn, cfg.quant)
                       .astype(jnp.float32))
    i = jax.nn.sigmoid(linear_apply(params["w_i"], xn, cfg.quant)
                       .astype(jnp.float32))
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r    # (B,S,D) f32
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xi.astype(jnp.float32))
    if cache is None or prefill:
        def combine(lhs, rhs):
            a1, b1 = lhs
            a2, b2 = rhs
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        new_cache = {"h": h[:, -1]} if prefill else None
    else:
        h = a[:, 0] * cache["h"] + b[:, 0]
        new_cache = {"h": h}
        h = h[:, None]
    y = linear_apply(params["w_out"], (h.astype(x.dtype) * gate), cfg.quant)
    return y.astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# xLSTM blocks (arXiv:2405.04517), chunkwise-parallel mLSTM + scanned sLSTM
# --------------------------------------------------------------------------

MLSTM_CHUNK = 64


def init_mlstm(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    hd = cfg.hd
    ks = jax.random.split(key, 6)
    return {
        "norm": jnp.ones((d,), jnp.float32),
        "w_q": linear_init(ks[0], d, h * hd, cfg.quant, cfg.dtype),
        "w_k": linear_init(ks[1], d, h * hd, cfg.quant, cfg.dtype),
        "w_v": linear_init(ks[2], d, h * hd, cfg.quant, cfg.dtype),
        "w_if": linear_init(ks[3], d, 2 * h, cfg.quant, cfg.dtype),
        "w_o": linear_init(ks[4], h * hd, d, cfg.quant, cfg.dtype),
    }


def cache_mlstm(cfg: ModelConfig, batch: int):
    h, hd = cfg.n_heads, cfg.hd
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32)}


def _mlstm_proj(params, x, cfg):
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    q = linear_apply(params["w_q"], xn, cfg.quant).reshape(b, s, h, hd)
    k = linear_apply(params["w_k"], xn, cfg.quant).reshape(b, s, h, hd) \
        * (hd ** -0.5)
    v = linear_apply(params["w_v"], xn, cfg.quant).reshape(b, s, h, hd)
    gif = linear_apply(params["w_if"], xn, cfg.quant).reshape(b, s, h, 2)
    log_i = gif[..., 0].astype(jnp.float32)               # input gate (log)
    log_f = -jax.nn.softplus(-gif[..., 1].astype(jnp.float32))  # log sigmoid
    return q, k, v, log_i, log_f


def apply_mlstm(params, x, cfg: ModelConfig, *, cache=None, prefill=False):
    """Matrix-memory LSTM; chunkwise parallel for sequences, one-step with
    cache for decode. Stabilizer-free formulation in f32 (DESIGN.md §8)."""
    b, s, _ = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q, k, v, log_i, log_f = _mlstm_proj(params, x, cfg)

    if cache is not None and not prefill:                  # decode step
        i_g = jnp.exp(log_i[:, 0])                         # (B,H)
        f_g = jnp.exp(log_f[:, 0])
        kv = jnp.einsum("bhk,bhv->bhkv", k[:, 0].astype(jnp.float32),
                        v[:, 0].astype(jnp.float32))
        C = f_g[..., None, None] * cache["C"] + i_g[..., None, None] * kv
        n = f_g[..., None] * cache["n"] + i_g[..., None] \
            * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhkv,bhk->bhv", C, q[:, 0].astype(jnp.float32))
        den = jnp.abs(jnp.einsum("bhk,bhk->bh", n,
                                 q[:, 0].astype(jnp.float32)))
        out = (num / jnp.maximum(den, 1.0)[..., None])[:, None]
        new_cache = {"C": C, "n": n}
    else:                                                  # chunkwise train
        c = MLSTM_CHUNK if s % MLSTM_CHUNK == 0 else s
        nc = s // c
        def resh(t):
            return t.reshape(b, nc, c, *t.shape[2:])
        qc, kc, vc = map(resh, (q.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32)))
        lic, lfc = map(resh, (log_i, log_f))
        F = jnp.cumsum(lfc, axis=2)                        # (B,NC,C,H)
        Ftot = F[:, :, -1]
        # intra-chunk: A[t,u] = exp(F_t - F_u + log i_u)  for u <= t
        decay = F[:, :, :, None, :] - F[:, :, None, :, :] + lic[:, :, None]
        tri = jnp.tril(jnp.ones((c, c), bool))
        A = jnp.where(tri[None, None, :, :, None], jnp.exp(decay), 0.0)
        scores = jnp.einsum("bnthd,bnuhd->bntuh", qc, kc) * A
        intra = jnp.einsum("bntuh,bnuhd->bnthd", scores, vc)
        n_intra = jnp.einsum("bntuh,bnuhd->bnthd", A, kc)
        # inter-chunk recurrence over chunk summaries
        w_end = jnp.exp(Ftot[:, :, None, :] - F + lic)     # (B,NC,C,H)
        kv_sum = jnp.einsum("bnuh,bnuhk,bnuhv->bnhkv", w_end, kc, vc)
        k_sum = jnp.einsum("bnuh,bnuhk->bnhk", w_end, kc)

        def step(carry, xs):
            C_in, n_in = carry
            kv_c, k_c, ftot = xs
            C_out = jnp.exp(ftot)[..., None, None] * C_in + kv_c
            n_out = jnp.exp(ftot)[..., None] * n_in + k_c
            return (C_out, n_out), (C_in, n_in)

        C0 = cache["C"] if cache is not None else \
            jnp.zeros((b, h, hd, hd), jnp.float32)
        n0 = cache["n"] if cache is not None else \
            jnp.zeros((b, h, hd), jnp.float32)
        (C_fin, n_fin), (C_hist, n_hist) = jax.lax.scan(
            step, (C0, n0),
            (jnp.moveaxis(kv_sum, 1, 0), jnp.moveaxis(k_sum, 1, 0),
             jnp.moveaxis(Ftot, 1, 0)))
        C_hist = jnp.moveaxis(C_hist, 0, 1)                # (B,NC,H,K,V)
        n_hist = jnp.moveaxis(n_hist, 0, 1)
        inter = jnp.einsum("bnthd,bnhdv->bnthv", qc * jnp.exp(F)[..., None],
                           C_hist)
        n_inter = n_hist[:, :, None] * jnp.exp(F)[..., None]
        num = intra + inter
        den = jnp.abs(jnp.einsum("bnthd,bnthd->bnth", qc,
                                 n_intra + n_inter))
        out = (num / jnp.maximum(den, 1.0)[..., None]).reshape(b, s, h, hd)
        new_cache = {"C": C_fin, "n": n_fin} if prefill else None

    y = linear_apply(params["w_o"],
                     out.reshape(b, -1, h * hd).astype(x.dtype), cfg.quant)
    return y.astype(x.dtype), new_cache


def init_slstm(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 9)
    def lin(k_, i, o):
        return linear_init(k_, i, o, cfg.quant, cfg.dtype)
    return {"norm": jnp.ones((d,), jnp.float32),
            "w_z": lin(ks[0], d, d), "r_z": lin(ks[1], d, d),
            "w_i": lin(ks[2], d, d), "r_i": lin(ks[3], d, d),
            "w_f": lin(ks[4], d, d), "r_f": lin(ks[5], d, d),
            "w_o": lin(ks[6], d, d), "r_o": lin(ks[7], d, d),
            "w_out": lin(ks[8], d, d)}


def cache_slstm(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def _slstm_step(params, cfg, state, xt):
    """One stabilized exponential-gated step. xt (B, D)."""
    h, c, n, m = state["h"], state["c"], state["n"], state["m"]
    hd_ = h.astype(xt.dtype)
    def gate(wk, rk):
        return (linear_apply(params[wk], xt, cfg.quant)
                + linear_apply(params[rk], hd_, cfg.quant)).astype(jnp.float32)
    z = jnp.tanh(gate("w_z", "r_z"))
    o = jax.nn.sigmoid(gate("w_o", "r_o"))
    log_i = gate("w_i", "r_i")
    log_f = -jax.nn.softplus(-gate("w_f", "r_f"))
    m_new = jnp.maximum(log_f + m, log_i)
    c_new = jnp.exp(log_f + m - m_new) * c + jnp.exp(log_i - m_new) * z
    n_new = jnp.exp(log_f + m - m_new) * n + jnp.exp(log_i - m_new)
    h_new = o * (c_new / jnp.maximum(n_new, 1e-6))
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def apply_slstm(params, x, cfg: ModelConfig, *, cache=None, prefill=False):
    b, s, d = x.shape
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    if cache is not None and not prefill:
        state = _slstm_step(params, cfg, cache, xn[:, 0])
        y = state["h"][:, None]
        new_cache = state
    else:
        state0 = cache if (prefill and cache is not None) \
            else cache_slstm(cfg, b)
        def body(st, xt):
            st = _slstm_step(params, cfg, st, xt)
            return st, st["h"]
        final, hs = jax.lax.scan(body, state0, jnp.moveaxis(xn, 1, 0))
        y = jnp.moveaxis(hs, 0, 1)
        new_cache = final if prefill else None
    y = linear_apply(params["w_out"], y.astype(x.dtype), cfg.quant)
    return y.astype(x.dtype), new_cache
