"""Attention: GQA self-attention (global/local/causal), cross-attention,
RoPE (incl. chatglm-style partial rotary), qk-norm, chunked (flash-style)
softmax for long sequences, rolling KV caches for local windows, and the
paper's dynamic int8 quantized attention GEMMs (Sec. 5.7: K/V treated as
weights with per-tile dynamic scoreboards → per-token dynamic quantization
on TPU).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.quant import quantize_per_token

NEG_INF = -1e30
CHUNK_THRESHOLD = 2048        # direct softmax below, chunked scan above
Q_CHUNK = 1024                # query-chunk size for the flash-style path
ATTN_UNROLL: int | bool = 1   # roofline calibration unrolls the chunk scan
                              # (HloCostAnalysis counts while bodies once)


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    out = (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * scale
    return out.astype(x.dtype)      # keep activations in the working dtype


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float,
         partial: bool = False) -> jnp.ndarray:
    """x (B, S, H, D), positions (B, S). partial=True rotates only the first
    half of head_dim (chatglm's 2d RoPE keeps half the dims positional)."""
    d = x.shape[-1]
    rot_d = d // 2 if partial else d
    freqs = theta ** (-jnp.arange(0, rot_d, 2, dtype=jnp.float32) / rot_d)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, rd/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot_d].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    out = out.reshape(xr.shape).astype(x.dtype)
    if partial:
        out = jnp.concatenate([out, x[..., rot_d:]], -1)
    return out


def _repeat_kv(k: jnp.ndarray, groups: int) -> jnp.ndarray:
    """(B, S, KV, D) -> (B, S, KV*groups, D)."""
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=2)


def _quantize_kv(t: jnp.ndarray):
    """KV8 cache quantization, pinned to float32 arithmetic.

    The per-token scale is max|t|/127 — computed in bf16 its final
    division may or may not keep the bf16 rounding depending on how XLA
    fuses it into the float32 cache store, so two programs writing the
    same K/V row (the dense prefill and the paged serve prefill) could
    store different scale bytes. Quantizing from f32 makes the stored
    (int8, scale) pair a pure function of the row values, program-shape
    independent — the bit-identity contract of repro.serve rests on it.

    The ``jax.named_scope`` tags every equation in this subgraph so the
    tracelint ``dtype-purity`` rule (repro.analysis) can statically
    reject any bf16 intermediate that sneaks back in — the rule anchors
    on the scope name, not on fragile equation positions.
    """
    with jax.named_scope("quantize_kv"):
        return quantize_per_token(t.astype(jnp.float32))


def _scores(q, k, scale, quant: bool):
    """einsum('bqhd,bkhd->bhqk'), optionally with dynamic-int8 operands —
    the TPU mapping of the paper's dynamic-scoreboard attention (Sec. 5.7:
    K/V treated as weights, quantized per tile at runtime)."""
    if quant:
        qq, sq = quantize_per_token(q)                    # (B,Sq,H,1)
        kk, sk = quantize_per_token(k)                    # (B,Sk,H,1)
        s32 = jnp.einsum("bqhd,bkhd->bhqk", qq, kk,
                         preferred_element_type=jnp.int32)
        sq_b = jnp.moveaxis(sq, 2, 1)                     # (B,H,Sq,1)
        sk_b = jnp.moveaxis(sk, 2, 1)[..., 0][:, :, None, :]  # (B,H,1,Sk)
        return s32.astype(jnp.float32) * sq_b * sk_b * scale
    return jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale


def _pv(p, v, quant: bool):
    """P (B,H,Sq,Sk) @ V (B,Sk,H,D) -> (B,Sq,H,D), optionally int8."""
    if quant:
        qp, sp = quantize_per_token(p)                    # rows over Sk
        sv = jnp.max(jnp.abs(v), axis=1, keepdims=True) / 127.0 + 1e-8
        qv = jnp.clip(jnp.round(v / sv), -128, 127).astype(jnp.int8)
        o32 = jnp.einsum("bhqk,bkhd->bqhd", qp, qv,
                         preferred_element_type=jnp.int32)
        return o32.astype(jnp.float32) * jnp.moveaxis(sp, 1, 2) * sv
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def attend_full(q, k, v, mask, scale, quant: bool = False):
    """Direct softmax attention. q (B,Sq,H,D), k/v (B,Sk,KV*,D) pre-repeat."""
    s = _scores(q, k, scale, quant)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _pv(p, v, quant)


def attend_chunked(q, k, v, scale, causal: bool, window: int,
                   q_offset: int | jnp.ndarray = 0,
                   kv_len: jnp.ndarray | None = None):
    """Q-chunked attention: scan over query chunks with a rematerialised
    chunk body. Each chunk sees full K/V (cheap: K/V are (B,Sk,H,D) in the
    working dtype), so no online-softmax state is carried — the (Cq, Sk)
    score tile is transient in both forward AND backward (flash-style
    memory: the scan body is jax.checkpoint'ed, so AD recomputes scores per
    chunk instead of stashing the (Sq, Sk) attention matrix).

    q (B,Sq,H,D); k/v (B,Sk,H,D) already GQA-repeated.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    cq = Q_CHUNK if sq % Q_CHUNK == 0 else sq
    nc = sq // cq
    qc = jnp.moveaxis(q.reshape(b, nc, cq, h, d), 1, 0)
    kpos = jnp.arange(sk)

    def body(_, xs):
        qch, ci = xs
        qpos = q_offset + ci * cq + jnp.arange(cq)
        s = jnp.einsum("bqhd,bkhd->bhqk", qch, k).astype(jnp.float32) * scale
        ok = jnp.ones((cq, sk), bool)
        if causal:
            ok &= qpos[:, None] >= kpos[None, :]
        if window:
            ok &= qpos[:, None] - kpos[None, :] < window
        if kv_len is not None:
            ok &= kpos[None, :] < kv_len
        s = jnp.where(ok[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
        return None, out

    _, outs = jax.lax.scan(jax.checkpoint(body), None,
                           (qc, jnp.arange(nc)), unroll=ATTN_UNROLL)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, d)  # (B, Sq, H, D)


def attend_cached(q, ck, cv, cks, cvs, valid, cfg: ModelConfig, scale,
                  sshard=None):
    """Decode-step attention against a contiguous (B, S, KV, D) cache view.

    q (B, Sq, H, D); ck/cv the cached keys/values — int8 with cks/cvs
    per-position scales for the KV8 layout, else the working dtype; valid
    (B', S) bool with B' in {1, B} — False keys are masked to NEG_INF.
    Grouped-head attention: the contraction runs against the cache directly
    in (KV, G) layout — no jnp.repeat materialisation of G x the cache
    (§Perf hillclimb 1, iteration 3). With a KV8 cache (iteration 4) the
    int8 values + stored scales feed the int GEMM directly. ``sshard``
    optionally constrains the score layout (the sequence-parallel dense
    decode path).

    This is the one implementation of cached-decode attention: the dense
    per-slot cache path AND the paged serve path both call it, so the two
    stay bit-identical by construction.
    """
    b, sq, h, hd = q.shape
    kv = ck.shape[2]
    groups = h // kv
    int8_cache = ck.dtype == jnp.int8
    qg = q.reshape(b, sq, kv, groups, hd)
    if cfg.quant_attention:
        qq, sqs = quantize_per_token(qg)             # (B,1,KV,G,1)
        if int8_cache:
            kk, sks = ck, cks
        else:
            kk, sks = quantize_per_token(ck)         # (B,S,KV,1)
        s32 = jnp.einsum("bqkgd,bskd->bkgqs", qq, kk,
                         preferred_element_type=jnp.int32)
        sk_b = sks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
        s = (s32.astype(jnp.float32) * scale
             * jnp.moveaxis(sqs, 1, 3)                # (B,KV,G,1,1)
             * sk_b)                                  # (B,KV,1,1,S)
    elif int8_cache:
        kf = ck.astype(jnp.float32) * cks
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                       kf) * scale
    else:
        s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck) \
            .astype(jnp.float32) * scale
    if sshard is not None:
        s = sshard(s)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if cfg.quant_attention:
        if int8_cache:
            # fold the per-position V scales into P before quantizing —
            # the int8 contraction then needs no per-s rescale.
            vs_b = cvs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
            qp, sps = quantize_per_token(p * vs_b)
            qv = cv
            sv_out = 1.0
        else:
            qp, sps = quantize_per_token(p)
            sv = jnp.max(jnp.abs(cv), axis=1, keepdims=True) / 127. + 1e-8
            qv = jnp.clip(jnp.round(cv / sv), -128, 127).astype(jnp.int8)
            sv_out = sv[:, :, :, None, :]
        o32 = jnp.einsum("bkgqs,bskd->bqkgd", qp, qv,
                         preferred_element_type=jnp.int32)
        out = (o32.astype(jnp.float32)
               * jnp.moveaxis(sps, -1, 1) * sv_out)
    elif int8_cache:
        vf = cv.astype(jnp.float32) * cvs
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, vf)
    else:
        out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(cv.dtype), cv)
    return out.reshape(b, sq, h, hd)


# --------------------------------------------------------------------------
# Block-level self/cross attention with cache handling
# --------------------------------------------------------------------------

def init_attn(key, cfg: ModelConfig, cross: bool = False):
    from repro.quant import linear_init
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 6)
    qcfg = cfg.quant
    p = {
        "norm": jnp.ones((cfg.d_model,), jnp.float32),
        "wq": linear_init(ks[0], cfg.d_model, h * hd, qcfg, cfg.dtype),
        "wk": linear_init(ks[1], cfg.d_model, kv * hd, qcfg, cfg.dtype),
        "wv": linear_init(ks[2], cfg.d_model, kv * hd, qcfg, cfg.dtype),
        "wo": linear_init(ks[3], h * hd, cfg.d_model, qcfg, cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), jnp.float32)
        p["k_norm"] = jnp.ones((hd,), jnp.float32)
    return p


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int,
                    window: int = 0, cross: bool = False):
    size = min(max_len, window) if window else max_len
    shape = (batch, size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_bits == 8 and not cross:
        # KV8: int8 cache + per-position scales (QServe-style; the paper's
        # "K/V as weights" under dynamic quantization, Sec. 5.7)
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:-1] + (1,), jnp.float32),
                "vs": jnp.zeros(shape[:-1] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, cfg.dtype), "v": jnp.zeros(shape, cfg.dtype)}


def apply_attn(params, x, cfg: ModelConfig, *, positions, cache=None,
               step=None, causal=True, window=0, context=None,
               prefill=False):
    """Self- or cross-attention block body (pre-norm, residual outside).

    Modes: train (cache=None, prefill=False), prefill (cache given — zeros —
    filled with the prompt's K/V and returned), decode (cache given,
    step-wise update). Returns (out, new_cache).
    """
    from repro.quant import linear_apply
    qcfg = cfg.quant
    b, sq, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    q = linear_apply(params["wq"], xn, qcfg).reshape(b, sq, h, hd)
    decode_cross = context is not None and cache is not None and not prefill
    if decode_cross:
        k = v = None                          # context K/V already cached
    else:
        src = context if context is not None else xn
        k = linear_apply(params["wk"], src, qcfg) \
            .reshape(b, src.shape[1], kv, hd)
        v = linear_apply(params["wv"], src, qcfg) \
            .reshape(b, src.shape[1], kv, hd)
        k = shard(k, "batch", None, "kv_heads", None)
        v = shard(v, "batch", None, "kv_heads", None)
    q = shard(q, "batch", None, "heads", None)

    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        if k is not None:
            k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    if context is None:                       # RoPE only for self-attention
        q = rope(q, positions, cfg.rope_theta, cfg.rope_2d)
        k = rope(k, positions, cfg.rope_theta, cfg.rope_2d)

    scale = hd ** -0.5
    new_cache = cache
    groups = h // kv

    if cache is not None and prefill:
        # write the prompt's K/V into the (possibly rolling) cache
        size = cache["k"].shape[1]
        src_len = k.shape[1]
        take = min(size, src_len)
        slots = (jnp.arange(take) + (src_len - take)) % size
        if cache["k"].dtype == jnp.int8:
            qk, ks = _quantize_kv(k[:, -take:])
            qv, vs = _quantize_kv(v[:, -take:])
            new_cache = {"k": cache["k"].at[:, slots].set(qk),
                         "v": cache["v"].at[:, slots].set(qv),
                         "ks": cache["ks"].at[:, slots].set(ks),
                         "vs": cache["vs"].at[:, slots].set(vs)}
        else:
            ck = cache["k"].at[:, slots].set(
                k[:, -take:].astype(cache["k"].dtype))
            cv = cache["v"].at[:, slots].set(
                v[:, -take:].astype(cache["v"].dtype))
            new_cache = {"k": ck, "v": cv}

    if context is not None and not decode_cross:      # cross, full pass
        kf = _repeat_kv(k, groups)
        vf = _repeat_kv(v, groups)
        mask = jnp.ones((b, 1, sq, kf.shape[1]), bool)
        out = attend_full(q, kf, vf, mask, scale, cfg.quant_attention)
    elif cache is None or prefill:            # train / prefill full pass
        kf = _repeat_kv(k, groups)
        vf = _repeat_kv(v, groups)
        if sq > CHUNK_THRESHOLD:
            out = attend_chunked(q, kf, vf, scale, causal, window)
        else:
            qp = positions[:, :, None]
            kp = positions[:, None, :]
            mask = jnp.ones((b, sq, sq), bool)
            if causal:
                mask &= qp >= kp
            if window:
                mask &= qp - kp < window
            out = attend_full(q, kf, vf, mask[:, None], scale,
                              cfg.quant_attention)
    else:                                     # decode step against cache
        size = cache["k"].shape[1]
        # Sequence-parallel decode (DESIGN.md §4): when GQA kv heads don't
        # divide the model axis, the cache is sharded on its sequence axis.
        # Without explicit constraints SPMD "involuntarily rematerializes"
        # (all-gathers) the cache every step — §Perf hillclimb 1.
        from repro.distributed.sharding import mesh_axis_size
        model_n = mesh_axis_size("model")
        seq_mode = (model_n > 1 and kv % model_n != 0
                    and size % model_n == 0)

        def cshard(t):
            return shard(t, "batch", "kv_seq", None, None) if seq_mode else t
        int8_cache = cache["k"].dtype == jnp.int8
        cks = cvs = None
        if decode_cross:
            ck, cv = cache["k"], cache["v"]
            kv_len = size
        else:
            slot = step % size if window else step

            def dus(buf, val):
                return jax.lax.dynamic_update_slice(
                    buf, val, (0, slot) + (0,) * (buf.ndim - 2))
            if int8_cache:
                qk_new, ks_new = _quantize_kv(k)
                qv_new, vs_new = _quantize_kv(v)
                ck = cshard(dus(cache["k"], qk_new))
                cv = cshard(dus(cache["v"], qv_new))
                cks = cshard(dus(cache["ks"], ks_new.astype(jnp.float32)))
                cvs = cshard(dus(cache["vs"], vs_new.astype(jnp.float32)))
                new_cache = {"k": ck, "v": cv, "ks": cks, "vs": cvs}
            else:
                ck = cshard(dus(cache["k"], k.astype(cache["k"].dtype)))
                cv = cshard(dus(cache["v"], v.astype(cache["v"].dtype)))
                new_cache = {"k": ck, "v": cv}
            kv_len = jnp.minimum(step + 1, size)
        valid = jnp.arange(size)[None, :] < kv_len
        sshard = ((lambda t: shard(t, "batch", None, None, None, "kv_seq"))
                  if seq_mode else None)
        out = attend_cached(q, ck, cv, cks, cvs, valid, cfg, scale,
                            sshard=sshard)

    out = out.reshape(b, sq, h * hd)
    y = linear_apply(params["wo"], out.astype(x.dtype), qcfg)
    return y.astype(x.dtype), new_cache


# --------------------------------------------------------------------------
# Paged KV cache (the continuous-batching serve path, repro.serve)
# --------------------------------------------------------------------------
#
# The pool is a static-shape pytree: (n_pages, page_size, KV, D) K/V buffers
# (+ per-position scales under KV8) shared by every slot, addressed through
# an int32 page table — the same static-gather trick DevicePlan uses for
# forest schedules, so decode is one fixed-shape jit regardless of which
# requests occupy which slots. Logical position p of a slot lives at
# (page_indices[slot, p // page_size], p % page_size); page 0 is the null
# page (never allocated — inactive slots point at it, masked writes land
# in it).

def init_attn_page_pool(cfg: ModelConfig, n_pages: int, page_size: int):
    """One attention layer's page pool (unstacked; Model stacks repeats)."""
    shape = (n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_cache_bits == 8:
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:-1] + (1,), jnp.float32),
                "vs": jnp.zeros(shape[:-1] + (1,), jnp.float32)}
    return {"k": jnp.zeros(shape, cfg.dtype),
            "v": jnp.zeros(shape, cfg.dtype)}


def _gather_pages(buf, page_indices):
    """(n_pages, ps, ...) gathered to a contiguous (B, P*ps, ...) view in
    logical-position order — position p of slot b lands at index p, so the
    downstream attention sees exactly the layout the dense cache has."""
    g = buf[page_indices]                       # (B, P, ps, ...)
    return g.reshape((g.shape[0], g.shape[1] * g.shape[2]) + g.shape[3:])


def apply_attn_paged_prefill(params, x, cfg: ModelConfig, *, pool,
                             prefix_page_ids, write_page_ids, write_offs,
                             write_from: int):
    """Suffix prefill for ONE request (B=1) against a page pool.

    ``x`` (1, Ls, d) embeds the prompt *suffix*: positions start..L-1 where
    ``start = len(prefix_page_ids) * page_size`` is the prefix-trie-shared
    range (0 when nothing is shared). The shared positions' K/V are
    gathered from the pool — bit-identical to recomputing them when the
    pool stores the working dtype, which is why the engine only skips
    computation for exact (non-KV8) pools. Suffix K/V for positions
    start+write_from..L-1 are written to ``(write_page_ids[i],
    write_offs[i])`` (``write_from`` > 0 lets a KV8 full-recompute skip
    re-writing pages it shares). Returns (out, new_pool).

    All lengths and index-array shapes are static: the jit retraces per
    (suffix_len, n_prefix_pages) pair — decode, by contrast, is a single
    shape (see :func:`apply_attn_paged_decode`).
    """
    from repro.quant import linear_apply
    qcfg = cfg.quant
    b, ls, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ps = pool["k"].shape[1]
    n_pre = len(prefix_page_ids)
    start = n_pre * ps
    total = start + ls
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    q = linear_apply(params["wq"], xn, qcfg).reshape(b, ls, h, hd)
    k = linear_apply(params["wk"], xn, qcfg).reshape(b, ls, kvh, hd)
    v = linear_apply(params["wv"], xn, qcfg).reshape(b, ls, kvh, hd)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    q = shard(q, "batch", None, "heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    qpos = jnp.broadcast_to(start + jnp.arange(ls), (b, ls))
    q = rope(q, qpos, cfg.rope_theta, cfg.rope_2d)
    k = rope(k, qpos, cfg.rope_theta, cfg.rope_2d)
    scale = hd ** -0.5

    # write the suffix K/V into this request's (private) pages — same
    # quantization as the dense prefill cache write
    int8_pool = pool["k"].dtype == jnp.int8
    new_pool = dict(pool)
    if int8_pool:
        qk, ks = _quantize_kv(k)
        qv, vs = _quantize_kv(v)
        stores = {"k": qk, "v": qv, "ks": ks, "vs": vs}
    else:
        stores = {"k": k, "v": v}
    for name, val in stores.items():
        rows = val[0, write_from:].astype(pool[name].dtype)
        new_pool[name] = pool[name].at[write_page_ids, write_offs].set(rows)

    # full K/V view: gathered shared prefix (exact working-dtype pools
    # only — the engine guarantees n_pre == 0 for KV8) + in-pass suffix
    if n_pre:
        k_pre = pool["k"][prefix_page_ids].reshape(1, start, kvh, hd)
        v_pre = pool["v"][prefix_page_ids].reshape(1, start, kvh, hd)
        k_full = jnp.concatenate([k_pre.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([v_pre.astype(v.dtype), v], axis=1)
    else:
        k_full, v_full = k, v
    groups = h // kvh
    kf = _repeat_kv(k_full, groups)
    vf = _repeat_kv(v_full, groups)
    # branch on the TOTAL length, mirroring the dense prefill's threshold
    # (a shared-prefix suffix must attend the same way the reference
    # full-prompt pass did)
    if total > CHUNK_THRESHOLD:
        out = attend_chunked(q, kf, vf, scale, causal=True, window=0,
                             q_offset=start)
    else:
        kpos = jnp.arange(total)
        mask = qpos[:, :, None] >= kpos[None, None, :]
        out = attend_full(q, kf, vf, mask[:, None], scale,
                          cfg.quant_attention)
    out = out.reshape(b, ls, h * hd)
    y = linear_apply(params["wo"], out.astype(x.dtype), qcfg)
    return y.astype(x.dtype), new_pool


def apply_attn_paged_prefill_batched(params, x, cfg: ModelConfig, *, pool,
                                     prefix_page_ids, prefix_lens,
                                     suffix_lens, write_page_ids, write_offs,
                                     write_pos):
    """Bucket-padded batched prefill: N requests' suffixes in ONE call.

    ``x`` (B, Lb, d) embeds each row's prompt suffix left-aligned and
    zero-padded to the bucket length Lb; row b's real extent is
    ``suffix_lens[b]``. ``prefix_page_ids`` (B, PPb) is the trie-shared
    prefix page table padded with the null page; ``prefix_lens[b]`` (a
    multiple of page_size) counts the row's real shared positions.
    Suffix K/V rows are written through ``(write_page_ids, write_offs)``
    (B, Lb) — ``write_pos[b, i]`` names the suffix row stored by write i,
    and dead write lanes target the null page. Returns (out, new_pool).

    Parity with the per-request path is per-row exact: positions, masks
    and stored bytes match :func:`apply_attn_paged_prefill` for every
    live lane, and padded K/V lanes are zeroed before attention so the
    int8-PV absmax scale (computed over the full padded extent under
    ``quant_attention``) sees ``max(|v|, 0) == max|v|`` — identical to
    the unpadded scale. Shapes are static per (B, Lb, PPb) bucket, which
    is what bounds the engine's prefill retraces to the bucket set.
    """
    from repro.quant import linear_apply
    qcfg = cfg.quant
    b, ls, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ps = pool["k"].shape[1]
    n_pre = prefix_page_ids.shape[1]
    start = n_pre * ps
    total = start + ls
    if total > CHUNK_THRESHOLD:
        raise NotImplementedError(
            "bucketed prefill is full-extent only; the engine falls back "
            "to per-request chunked prefill above CHUNK_THRESHOLD")
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    q = linear_apply(params["wq"], xn, qcfg).reshape(b, ls, h, hd)
    k = linear_apply(params["wk"], xn, qcfg).reshape(b, ls, kvh, hd)
    v = linear_apply(params["wv"], xn, qcfg).reshape(b, ls, kvh, hd)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    q = shard(q, "batch", None, "heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    qpos = prefix_lens[:, None] + jnp.arange(ls)[None, :]       # (B, Lb)
    q = rope(q, qpos, cfg.rope_theta, cfg.rope_2d)
    k = rope(k, qpos, cfg.rope_theta, cfg.rope_2d)
    scale = hd ** -0.5

    # scatter each row's suffix K/V through its write lanes; lane i stores
    # suffix row write_pos[b, i] (rows, not a slice, so KV8 full-recompute
    # rows can skip re-writing shared pages); dead lanes hit the null page
    int8_pool = pool["k"].dtype == jnp.int8
    new_pool = dict(pool)
    if int8_pool:
        qk, ks = _quantize_kv(k)
        qv, vs = _quantize_kv(v)
        stores = {"k": qk, "v": qv, "ks": ks, "vs": vs}
    else:
        stores = {"k": k, "v": v}
    for name, val in stores.items():
        rows = jnp.take_along_axis(
            val, write_pos[:, :, None, None], axis=1)           # (B, Lb, ...)
        new_pool[name] = pool[name].at[write_page_ids, write_offs].set(
            rows.astype(pool[name].dtype))

    # full K/V view per row: gathered shared prefix (exact pools only —
    # the engine guarantees prefix_lens == 0 for KV8) + in-pass suffix.
    # Padded lanes are zeroed: masked out of the scores anyway, but the
    # quant-attention PV absmax must not see gathered/padded garbage.
    suf_idx = jnp.arange(ls)
    suf_valid = suf_idx[None, :] < suffix_lens[:, None]         # (B, Lb)
    if n_pre:
        pre_valid = jnp.arange(start)[None, :] < prefix_lens[:, None]
        k_pre = pool["k"][prefix_page_ids].reshape(b, start, kvh, hd)
        v_pre = pool["v"][prefix_page_ids].reshape(b, start, kvh, hd)
        k_full = jnp.concatenate([k_pre.astype(k.dtype), k], axis=1)
        v_full = jnp.concatenate([v_pre.astype(v.dtype), v], axis=1)
        key_valid = jnp.concatenate([pre_valid, suf_valid], axis=1)
    else:
        k_full, v_full = k, v
        key_valid = suf_valid
    k_full = jnp.where(key_valid[:, :, None, None], k_full, 0)
    v_full = jnp.where(key_valid[:, :, None, None], v_full, 0)
    groups = h // kvh
    kf = _repeat_kv(k_full, groups)
    vf = _repeat_kv(v_full, groups)
    # per-row causal mask in logical positions: a prefix lane t is visible
    # iff real (qpos >= prefix_lens > t always holds); suffix lane j is
    # visible to query i iff j <= i and j is real — identical lane-for-lane
    # to the per-request qpos >= kpos mask
    causal = suf_idx[None, :, None] >= suf_idx[None, None, :]   # (1, Lb, Lb)
    mask_suf = causal & suf_valid[:, None, :]
    if n_pre:
        mask_pre = jnp.broadcast_to(pre_valid[:, None, :], (b, ls, start))
        mask = jnp.concatenate([mask_pre, mask_suf], axis=2)
    else:
        mask = mask_suf
    out = attend_full(q, kf, vf, mask[:, None], scale, cfg.quant_attention)
    out = out.reshape(b, ls, h * hd)
    y = linear_apply(params["wo"], out.astype(x.dtype), qcfg)
    return y.astype(x.dtype), new_pool


def apply_attn_paged_decode(params, x, cfg: ModelConfig, *, pool,
                            page_indices, steps, kernel: bool | None = None):
    """One paged decode step over all slots. x (B, 1, d); page_indices
    (B, P) int32; steps (B,) int32 — the logical position the new token is
    written at (== tokens held so far). Returns (out, new_pool).

    Inactive slots carry a page table of null pages (page 0) and step 0:
    their writes land in the null page and their rows are garbage the
    scheduler never reads — the shapes never change, so decode re-traces
    exactly once per engine regardless of arrivals/evictions.

    ``kernel`` (default ``cfg.paged_kernel``) routes attention through the
    Pallas live-page kernel (:mod:`repro.kernels.paged_attention`), which
    walks only ``steps // page_size + 1`` pages per slot instead of
    gathering the full ``pages_per_slot`` extent. The gather +
    :func:`attend_cached` path below stays as the differential oracle.
    """
    from repro.quant import linear_apply
    qcfg = cfg.quant
    b, sq, _ = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ps = pool["k"].shape[1]
    xn = rms_norm(x, params["norm"], cfg.norm_eps)
    q = linear_apply(params["wq"], xn, qcfg).reshape(b, sq, h, hd)
    k = linear_apply(params["wk"], xn, qcfg).reshape(b, sq, kvh, hd)
    v = linear_apply(params["wv"], xn, qcfg).reshape(b, sq, kvh, hd)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    q = shard(q, "batch", None, "heads", None)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos = steps[:, None].astype(jnp.int32)
    q = rope(q, pos, cfg.rope_theta, cfg.rope_2d)
    k = rope(k, pos, cfg.rope_theta, cfg.rope_2d)
    scale = hd ** -0.5

    # scatter the new K/V row per slot: logical position steps[b] lives at
    # (page_indices[b, steps[b] // ps], steps[b] % ps)
    page = jnp.take_along_axis(page_indices, (steps // ps)[:, None],
                               axis=1)[:, 0]
    off = steps % ps
    int8_pool = pool["k"].dtype == jnp.int8
    if int8_pool:
        qk, ks = _quantize_kv(k)
        qv, vs = _quantize_kv(v)
        stores = {"k": qk, "v": qv, "ks": ks, "vs": vs}
    else:
        stores = {"k": k, "v": v}
    new_pool = dict(pool)
    for name, val in stores.items():
        new_pool[name] = pool[name].at[page, off].set(
            val[:, 0].astype(pool[name].dtype))

    if kernel is None:
        kernel = cfg.paged_kernel
    if kernel:
        from repro.kernels.paged_attention import paged_attention
        out = paged_attention(q, new_pool, page_indices, steps, cfg, scale)
    else:
        ck = _gather_pages(new_pool["k"], page_indices)
        cv = _gather_pages(new_pool["v"], page_indices)
        cks = _gather_pages(new_pool["ks"], page_indices) if int8_pool \
            else None
        cvs = _gather_pages(new_pool["vs"], page_indices) if int8_pool \
            else None
        size = ck.shape[1]
        valid = jnp.arange(size)[None, :] < \
            jnp.minimum(steps + 1, size)[:, None]
        out = attend_cached(q, ck, cv, cks, cvs, valid, cfg, scale)
    out = out.reshape(b, sq, h * hd)
    y = linear_apply(params["wo"], out.astype(x.dtype), qcfg)
    return y.astype(x.dtype), new_pool
