"""Public jit'd wrappers for the Pallas kernels (padding, batching, fallback).

``interpret`` defaults to auto: Pallas-TPU lowering on TPU backends,
interpret mode elsewhere (the CPU container validates kernel semantics).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.transitive_gemm import transitive_gemm_pallas
from repro.kernels.transitive_forest import transitive_forest
from repro.kernels.w4a8_gemm import w4a8_gemm_pallas
from repro.kernels.rg_lru import rg_lru_pallas

__all__ = ["transitive_gemm", "transitive_gemm_grouped", "transitive_forest",
           "w4a8_gemm", "rg_lru", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def transitive_gemm(qx: jnp.ndarray, qw: jnp.ndarray, *, w_bits: int = 8,
                    t: int = 8, interpret: bool | None = None) -> jnp.ndarray:
    """int32 [qx (..., K)] @ [qw (N, K)]^T via the transitive LUT kernel."""
    if interpret is None:
        interpret = default_interpret()
    batch = qx.shape[:-1]
    k = qx.shape[-1]
    n = qw.shape[0]
    x2 = qx.reshape(-1, k)
    m = x2.shape[0]
    bm = 128 if m >= 128 else 8
    bn = 64 if n >= 64 else 8
    bk = 256 if k % 256 == 0 else t
    x2 = _pad_to(x2, 0, bm)
    qwp = _pad_to(qw, 0, bn)
    out = transitive_gemm_pallas(x2, qwp, w_bits=w_bits, t=t, bm=bm, bn=bn,
                                 bk=bk, interpret=interpret)
    return out[:m, :n].reshape(batch + (n,))


def transitive_gemm_grouped(xg: jnp.ndarray, wg: jnp.ndarray, *,
                            w_bits: int = 8, t: int = 8,
                            interpret: bool | None = None) -> jnp.ndarray:
    """xg (..., G, g) x wg (N, G, g) -> (..., G, N) int32 group partials."""
    G = xg.shape[-2]
    outs = [transitive_gemm(xg[..., gi, :], wg[:, gi, :], w_bits=w_bits, t=t,
                            interpret=interpret) for gi in range(G)]
    return jnp.stack(outs, axis=-2)


def w4a8_gemm(qx: jnp.ndarray, sx: jnp.ndarray, qw: jnp.ndarray,
              sg: jnp.ndarray, *, group: int = 128,
              interpret: bool | None = None) -> jnp.ndarray:
    """f32 (..., N): fused group-dequant GEMM (MXU hot path)."""
    if interpret is None:
        interpret = default_interpret()
    batch = qx.shape[:-1]
    k = qx.shape[-1]
    n = qw.shape[0]
    x2 = qx.reshape(-1, k)
    s2 = sx.reshape(-1, 1)
    m = x2.shape[0]
    bm = 128 if m >= 128 else 8
    bn = 128 if n >= 128 else 8
    x2 = _pad_to(x2, 0, bm)
    s2 = _pad_to(s2, 0, bm)
    qwp = _pad_to(qw, 0, bn)
    sgp = _pad_to(sg, 0, bn)
    bk = 512 if k % 512 == 0 else group
    out = w4a8_gemm_pallas(x2, s2, qwp, sgp, group=group, bm=bm, bn=bn,
                           bk=bk, interpret=interpret)
    return out[:m, :n].reshape(batch + (n,))


def rg_lru(x: jnp.ndarray, a: jnp.ndarray, h0: jnp.ndarray, *,
           interpret: bool | None = None) -> jnp.ndarray:
    """Blocked linear recurrence h_t = a_t h_{t-1} + x_t over (B, S, D)."""
    if interpret is None:
        interpret = default_interpret()
    b, s, d = x.shape
    bb = 8 if b % 8 == 0 else 1
    bs = 256 if s % 256 == 0 else s
    bd = 256 if d % 256 == 0 else d
    return rg_lru_pallas(x, a, h0, bb=bb, bs=bs, bd=bd, interpret=interpret)
