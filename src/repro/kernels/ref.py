"""Pure-jnp oracles for every Pallas kernel (bit-exact, shape-flexible).

The transitive references execute the paper's result-reuse dataflow with a
*dense doubling LUT*: per T-wide k-tile, all 2^T subset sums of the input
rows are built in T vectorised concat-add steps —
``LUT[p] = LUT[p & (p-1)] + x[lsb(p)]`` — i.e. the complete Hasse graph with
every node's prefix at distance 1 (DESIGN.md §2). Weight TransRows then
gather their subset sum and shift-accumulate across bit planes with
2's-complement signs. This is bit-exact with the plain int matmul.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import bitslice

__all__ = ["lut_build_ref", "transitive_matmul_ref",
           "transitive_matmul_grouped_ref", "w4a8_matmul_ref", "rg_lru_ref"]


def lut_build_ref(xt: jnp.ndarray) -> jnp.ndarray:
    """Subset-sum LUT by doubling. xt (..., t) int -> (..., 2^t) int32."""
    t = xt.shape[-1]
    lut = jnp.zeros(xt.shape[:-1] + (1,), jnp.int32)
    for b in range(t):
        lut = jnp.concatenate([lut, lut + xt[..., b:b + 1].astype(jnp.int32)],
                              axis=-1)
    return lut


def _transrows(qw: jnp.ndarray, w_bits: int, t: int) -> jnp.ndarray:
    """(N, K) int -> (S, N, K//t) uint32 TransRow patterns (jit-safe)."""
    planes = bitslice.bit_planes_jnp(qw.astype(jnp.int32), w_bits)
    return bitslice.pack_transrows_jnp(planes, t)


def transitive_matmul_ref(qx: jnp.ndarray, qw: jnp.ndarray,
                          w_bits: int = 8, t: int = 8) -> jnp.ndarray:
    """int32 [qx (..., K)] @ [qw (N, K)]^T via transitive-reuse execution."""
    k = qx.shape[-1]
    n = qw.shape[0]
    assert qw.shape[1] == k and k % t == 0, (qx.shape, qw.shape, t)
    rows = _transrows(qw, w_bits, t)                     # (S, N, J)
    signs = jnp.asarray(bitslice.plane_signs(w_bits), jnp.int32)
    xt = qx.reshape(qx.shape[:-1] + (k // t, t))
    lut = lut_build_ref(xt)                              # (..., J, 2^t)
    out = jnp.zeros(qx.shape[:-1] + (n,), jnp.int32)
    j_idx = jnp.arange(k // t)
    for s in range(w_bits):
        # gather LUT[..., j, rows[s, n, j]] and reduce over j
        g = lut[..., j_idx[None, :], rows[s]]            # (..., N, J)
        out = out + signs[s] * g.sum(-1)
    return out


def transitive_matmul_grouped_ref(xg: jnp.ndarray, wg: jnp.ndarray,
                                  w_bits: int = 8, t: int = 8) -> jnp.ndarray:
    """Grouped variant: xg (..., G, g) x wg (N, G, g) -> (..., G, N) int32."""
    n, G, g = wg.shape
    outs = []
    for gi in range(G):
        outs.append(transitive_matmul_ref(xg[..., gi, :], wg[:, gi, :],
                                          w_bits, t))
    return jnp.stack(outs, axis=-2)


def w4a8_matmul_ref(qx: jnp.ndarray, sx: jnp.ndarray, qw: jnp.ndarray,
                    sg: jnp.ndarray, out_dtype=jnp.float32) -> jnp.ndarray:
    """Group-dequant GEMM oracle: qx (M, K) i8, sx (M, 1) f32,
    qw (N, K) i8, sg (N, K//group) f32 -> (M, N) f32."""
    m, k = qx.shape
    n, G = sg.shape[0], sg.shape[1]
    g = k // G
    xg = qx.reshape(m, G, g)
    wg = qw.reshape(n, G, g)
    part = jnp.einsum("mgi,ngi->mgn", xg, wg,
                      preferred_element_type=jnp.int32)
    y = jnp.einsum("mgn,ng->mn", part.astype(jnp.float32), sg)
    return (y * sx).astype(out_dtype)


def rg_lru_ref(x: jnp.ndarray, a: jnp.ndarray, h0: jnp.ndarray) -> jnp.ndarray:
    """Linear recurrence oracle: h_t = a_t * h_{t-1} + x_t.

    x, a: (B, S, D); h0: (B, D). Returns h (B, S, D) (f32 math).
    """
    def step(h, inp):
        at, xt = inp
        h = at * h + xt
        return h, h
    import jax
    xs = (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(x, 1, 0).astype(jnp.float32))
    _, hs = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return jnp.moveaxis(hs, 0, 1).astype(x.dtype)
