"""Pallas kernel: live-page paged-attention decode over the serve pool.

The pure-jnp decode path (``models.attention.apply_attn_paged_decode``)
gathers the **full** ``pages_per_slot * page_size`` KV extent per slot per
step — at production ``max_len`` that gather is the decode memory hot
spot, and almost all of it is dead: a request that has produced 40
positions touches 3 pages, not 64. This kernel is the "pay only for live
state" counterpart (the serving twin of the paper's transitive reuse
argument): one grid step owns one slot, reads that slot's row of the
``(n_slots, pages_per_slot)`` page table plus its step count, and walks
only the ``steps // page_size + 1`` **live** pages. Dead pages are never
loaded — the walks are ``lax.scan``s over the page axis whose per-page
``lax.cond`` skips the loads and substitutes a ``NEG_INF`` score tile /
zero PV partial, so the work per slot is proportional to its live length,
every shape stays static, and the traced program stays O(1) equations no
matter how large ``pages_per_slot`` grows.

Parity with the gather path (the differential oracle, kept in
``apply_attn_paged_decode``) is by construction, not by tolerance:

* **scores** contract only over ``head_dim`` — each (kv, group, lane)
  score is an independent dot of the same two rows, so per-page tiles are
  bitwise slices of the full score matrix;
* the **softmax** runs over the full static extent with dead lanes at
  exactly ``NEG_INF`` (what the oracle's mask produces), so dead lanes
  collapse to exactly ``0.0``;
* the **P·V** contraction is int32 under ``quant_attention`` (exact under
  any page grouping); the float layouts accumulate per-page partials in
  f32, differing from the oracle's single dot only in f32 summation
  order — the same class of difference the suffix-prefill path already
  carries, and the engine's bit-identity bar (argmax tokens) is pinned by
  tests/test_serve_engine.py either way;
* interpret-mode pallas compiles ``x / <literal>`` to a reciprocal
  multiply (1 ulp off exact division, which is what the oracle's jit
  emits), so the in-kernel quantizers divide by ``qmax`` passed as a
  runtime operand — array-denominator division is exact on both sides.

All four pool layouts are covered (exact/int8 pool x quant_attention
on/off), mirroring ``attend_cached`` operation-for-operation — including
multiplication order of the scale factors and the working dtype of every
``quantize_per_token`` call, which is what makes the int8 layouts
bit-exact. Like the sibling kernels this runs interpret-mode on CPU; a
silicon lowering would stream K/V pages through VMEM with the same table
walk (the page table row and step count are scalar-prefetch operands).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.quant import quantize_per_token

__all__ = ["paged_attention"]

NEG_INF = -1e30      # == models.attention.NEG_INF (kernels stay model-free)


def _quantize_rows(x, qmax):
    """``quantize_per_token`` with the quantization max as a traced array
    (``qmax`` (1,) f32 holding 127.0): bitwise the same math, but the
    divisions keep an array denominator so interpret-mode pallas cannot
    constant-fold them into reciprocal multiplies."""
    qm = qmax.astype(x.dtype)[0]
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / qm
    q = jnp.clip(jnp.round(x / scale), -128, 127).astype(jnp.int8)
    return q, scale


def _decode_kernel(*refs, quant: bool, int8_pool: bool, pages: int,
                   ps: int, scale: float):
    """One slot: cond-guarded live-page walk -> full-extent softmax ->
    cond-guarded live-page P·V accumulation."""
    refs = list(refs)
    table_ref, steps_ref, q_ref = refs[:3]
    refs = refs[3:]
    sq_ref = None
    if quant:
        sq_ref, refs = refs[0], refs[1:]
    kpool_ref, vpool_ref = refs[:2]
    refs = refs[2:]
    kspool_ref = vspool_ref = None
    if int8_pool:
        (kspool_ref, vspool_ref), refs = refs[:2], refs[2:]
    qmax_ref, out_ref = refs
    qmax = qmax_ref[...]                              # (1,) f32: 127.0

    qh = q_ref[0]                                     # (KV, G, hd)
    kv, g, hd = qh.shape
    step = steps_ref[0]
    n_live = step // ps + 1                           # pages holding rows
    sq = sq_ref[0] if quant else None                 # (KV, G, 1)
    s_full = pages * ps

    # ---- phase 1: per-page score tiles (+ per-page V metadata) ----------
    def score_tile(pid):
        kpage = kpool_ref[pid]                        # (ps, KV, hd)
        if quant:
            if int8_pool:
                kk, sks = kpage, kspool_ref[pid]      # stored f32 scales
            else:
                kk, sks = _quantize_rows(kpage, qmax)  # pool-dtype scales
            s32 = jnp.einsum("kgd,skd->kgs", qh, kk,
                             preferred_element_type=jnp.int32)
            sk_b = sks[..., 0].T[:, None, :]          # (KV, 1, ps)
            return s32.astype(jnp.float32) * scale * sq * sk_b
        if int8_pool:
            kf = kpage.astype(jnp.float32) * kspool_ref[pid]
            return jnp.einsum("kgd,skd->kgs", qh, kf) * scale
        return jnp.einsum("kgd,skd->kgs", qh, kpage) \
            .astype(jnp.float32) * scale

    def vmeta_tile(pid):
        """Per-page V metadata the P·V phase needs at full extent: stored
        per-position V scales (int8 pool fold) or the page's |V| max
        (dynamic re-quantization). Dead table entries point at the null
        page (pid 0), matching what the oracle's gather would read."""
        if quant and int8_pool:
            return vspool_ref[pid][..., 0].T           # (KV, ps)
        if quant:
            return jnp.max(jnp.abs(vpool_ref[pid]), axis=0)   # (KV, hd)
        return None

    # the page walks are lax.scans over the (static) page axis, not
    # Python-unrolled loops: the traced program stays O(1) equations no
    # matter how large pages_per_slot is (an unrolled walk at
    # max_len=512/page_size=4 is 128 conds per phase per layer — the
    # trace/compile cost swamps the live-page saving), while the op
    # order per page is identical, so results stay bitwise the same
    neg = jnp.full((kv, g, ps), NEG_INF, jnp.float32)
    idx = jnp.arange(pages, dtype=jnp.int32)

    def tile_step(vacc, j):
        pid = table_ref[0, j]
        parts = jax.lax.cond(
            j < n_live,
            lambda: (score_tile(pid), vmeta_tile(pid)),
            lambda: (neg, vmeta_tile(jnp.int32(0))))   # the null page
        if quant and int8_pool:                        # stack stored scales
            return None, parts
        if quant:                                      # running |V| max
            return jnp.maximum(vacc, parts[1]), parts[0]
        return None, parts[0]                          # no V metadata

    if quant and int8_pool:
        _, (tiles, vs_pages) = jax.lax.scan(tile_step, None, idx)
        vmeta = jnp.transpose(vs_pages, (1, 0, 2)) \
            .reshape(kv, s_full)                       # (KV, S)
    elif quant:
        vmax0 = jnp.full((kv, hd), -jnp.inf, vpool_ref.dtype)
        vmax, tiles = jax.lax.scan(tile_step, vmax0, idx)
    else:
        _, tiles = jax.lax.scan(tile_step, None, idx)
    s = jnp.transpose(tiles, (1, 2, 0, 3)) \
        .reshape(kv, g, s_full)                        # (KV, G, S)
    lane = jax.lax.broadcasted_iota(jnp.int32, (s_full,), 0)
    valid = lane < jnp.minimum(step + 1, s_full)       # == the oracle mask
    s = jnp.where(valid[None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)                     # dead lanes -> 0.0

    # ---- phase 2: live-page P·V accumulation ----------------------------
    def walk(acc, partial):
        """Scan the page axis, accumulating live pages' partials in page
        order (the same left-to-right order the unrolled loop used)."""
        def step(a, j):
            pid = table_ref[0, j]
            return jax.lax.cond(j < n_live,
                                lambda a: a + partial(pid, j),
                                lambda a: a, a), None
        acc, _ = jax.lax.scan(step, acc, idx)
        return acc

    def ptile(pr, j):
        """pr[..., j*ps:(j+1)*ps] with a traced page index."""
        return jax.lax.dynamic_slice_in_dim(pr, j * ps, ps, axis=2)

    if quant and int8_pool:
        # fold the stored per-position V scales into P before quantizing
        # (attend_cached's int8-pool path) — the int8 contraction then
        # accumulates exactly, page by page
        vs_b = vmeta[:, None, :]                             # (KV, 1, S)
        qp, sps = _quantize_rows(p * vs_b, qmax)
        o32 = walk(jnp.zeros((kv, g, hd), jnp.int32),
                   lambda pid, j: jnp.einsum(
                       "kgs,skd->kgd", ptile(qp, j), vpool_ref[pid],
                       preferred_element_type=jnp.int32))
        out_ref[0] = o32.astype(jnp.float32) * sps
    elif quant:
        qp, sps = _quantize_rows(p, qmax)
        # |V| max over the gathered extent == max over per-page maxes
        # (dead entries contribute the null page, as the gather would)
        sv = vmax / qmax.astype(vmax.dtype)[0] + 1e-8  # (KV, hd), pool dtype

        def pv(pid, j):
            qv = jnp.clip(jnp.round(vpool_ref[pid] / sv),
                          -128, 127).astype(jnp.int8)
            return jnp.einsum("kgs,skd->kgd", ptile(qp, j), qv,
                              preferred_element_type=jnp.int32)
        o32 = walk(jnp.zeros((kv, g, hd), jnp.int32), pv)
        out_ref[0] = o32.astype(jnp.float32) * sps * sv[:, None, :]
    elif int8_pool:
        out_ref[0] = walk(
            jnp.zeros((kv, g, hd), jnp.float32),
            lambda pid, j: jnp.einsum(
                "kgs,skd->kgd", ptile(p, j),
                vpool_ref[pid].astype(jnp.float32) * vspool_ref[pid]))
    else:
        pc = p.astype(vpool_ref.dtype)
        out_ref[0] = walk(
            jnp.zeros((kv, g, hd), jnp.float32),
            lambda pid, j: jnp.einsum(
                "kgs,skd->kgd", ptile(pc, j), vpool_ref[pid],
                preferred_element_type=jnp.float32))


def paged_attention(q, pool, page_indices, steps, cfg, scale, *,
                    interpret: bool | None = None):
    """Live-page decode attention. ``q`` (B, 1, H, hd) post-RoPE;
    ``pool`` one layer's page-pool leaves (n_pages, ps, KV, hd) (+ scale
    leaves under KV8); ``page_indices`` (B, P) int32; ``steps`` (B,)
    int32 — the position written this step. Returns (B, 1, H, hd) in the
    dtype ``attend_cached`` would produce for the same layout."""
    if interpret is None:
        from repro.kernels import ops
        interpret = ops.default_interpret()
    b, sq_len, h, hd = q.shape
    if sq_len != 1:
        raise ValueError(f"decode kernel expects Sq == 1, got {sq_len}")
    ps, kvh = pool["k"].shape[1], pool["k"].shape[2]
    g = h // kvh
    pages = page_indices.shape[1]
    quant = cfg.quant_attention
    int8_pool = pool["k"].dtype == jnp.int8
    qg = q.reshape(b, kvh, g, hd)

    def full(a):
        return pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd)

    inputs = [page_indices.astype(jnp.int32), steps.astype(jnp.int32)]
    in_specs = [pl.BlockSpec((1, pages), lambda i: (i, 0)),
                pl.BlockSpec((1,), lambda i: (i,))]
    qspec = pl.BlockSpec((1, kvh, g, hd), lambda i: (i, 0, 0, 0))
    if quant:
        qq, sqs = quantize_per_token(qg)       # pool-dtype scale, like the
        inputs += [qq, sqs]                    # oracle's quantize of q
        in_specs += [qspec, pl.BlockSpec((1, kvh, g, 1),
                                         lambda i: (i, 0, 0, 0))]
    else:
        # the int8-pool float path contracts q in f32 (oracle casts)
        inputs.append(qg.astype(jnp.float32) if int8_pool else qg)
        in_specs.append(qspec)
    names = ("k", "v", "ks", "vs") if int8_pool else ("k", "v")
    for name in names:
        inputs.append(pool[name])
        in_specs.append(full(pool[name]))
    # 127.0 as a runtime operand: a literal denominator would let the
    # interpret-mode compiler fold the quantizer divisions into reciprocal
    # multiplies, 1 ulp off the oracle's exact division
    qmax = jnp.full((1,), 127.0, jnp.float32)
    inputs.append(qmax)
    in_specs.append(pl.BlockSpec((1,), lambda i: (0,)))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, quant=quant, int8_pool=int8_pool,
                          pages=pages, ps=ps, scale=scale),
        grid=(b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kvh, g, hd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), jnp.float32),
        interpret=interpret,
    )(*inputs)
    out = out.reshape(b, 1, h, hd)
    if not quant and not int8_pool:
        out = out.astype(pool["v"].dtype)      # the oracle's bf16 P·V dot
    return out
