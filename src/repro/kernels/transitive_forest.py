"""Pallas kernel: the level-synchronous Scoreboard forest from a DevicePlan.

Where kernels/transitive_gemm.py rebuilds the *complete* subset-sum LUT per
k-subtile (data-independent doubling), this kernel executes the paper's
actual data-dependent schedule — the gather-only per-level source maps plus
the direct-dispatch and APE shift-accumulate passes — straight from the
same :class:`~repro.core.engine.DevicePlan` index arrays the pure-jnp
``run_device`` uses. One grid step owns one block of activation columns;
the plan arrays are broadcast to every step. Each level advances the whole
psum table as ``psum[src] + x[xsrc]`` (identity lanes gather themselves
plus a pinned zero row), identical to the jnp path, so the kernel is
bit-exact with ``run_device`` and with the ``int_dot`` int32 accumulator.

Like the sibling kernels this runs in interpret mode on CPU (the container
validates semantics); the VMEM story on real silicon is the psum table
(J * 2^T, bm) int32 — e.g. K=4096, T=8, bm=64: 32 MiB, so a hardware
lowering would tile K as well and accumulate group partials across a k
grid axis. That step is deliberately left to a TPU-silicon PR.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.engine import DevicePlan

__all__ = ["transitive_forest", "transitive_forest_pallas"]


def _kernel(x_ref, src_ref, xsrc_ref, didx_ref, dxidx_ref,
            dbits_ref, gat_ref, signs_ref, out_ref, *, t, groups, n, k):
    # the schedule itself is engine.forest_body — one shared jnp body, not
    # a hand-synced copy, so kernel and run_device cannot drift apart
    from repro.core.engine import forest_body
    x = x_ref[...].astype(jnp.int32)                       # (K, bm)
    out = forest_body(x, src_ref[...], xsrc_ref[...], didx_ref[...],
                      dxidx_ref[...], dbits_ref[...], gat_ref[...],
                      signs_ref[...], t=t, groups=groups, n=n, k=k)
    out_ref[...] = out.reshape(n * groups, x.shape[1])


@functools.partial(jax.jit, static_argnames=("t", "groups", "n", "k", "bm",
                                             "interpret"))
def transitive_forest_pallas(x, level_src, level_xsrc, direct_idx,
                             direct_x_idx, direct_bits, gather_idx, signs, *,
                             t, groups, n, k, bm, interpret=True):
    """Raw pallas_call over the plan leaves; x (K, M) with M % bm == 0."""
    m = x.shape[1]
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    return pl.pallas_call(
        functools.partial(_kernel, t=t, groups=groups, n=n, k=k),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((k, bm), lambda i: (0, i)),
            full(level_src), full(level_xsrc),
            full(direct_idx), full(direct_x_idx), full(direct_bits),
            full(gather_idx), full(signs),
        ],
        out_specs=pl.BlockSpec((n * groups, bm), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n * groups, m), jnp.int32),
        interpret=interpret,
    )(x, level_src, level_xsrc, direct_idx, direct_x_idx,
      direct_bits, gather_idx, signs)


def transitive_forest(dplan: DevicePlan, x: jnp.ndarray, *,
                      bm: int | None = None,
                      interpret: bool | None = None) -> jnp.ndarray:
    """Forest execution of ``x`` (K, M) via the Pallas kernel.

    Same contract as :func:`repro.core.engine.run_device`: int32 (N, M)
    ungrouped, (N, G, M) grouped. Pads M up to the block width and slices
    the result back.
    """
    if interpret is None:
        from repro.kernels import ops     # deferred: ops imports this module
        interpret = ops.default_interpret()
    if x.ndim != 2 or x.shape[0] != dplan.k:
        raise ValueError(f"x must be (K={dplan.k}, M), got {x.shape}")
    m = x.shape[1]
    # decode-sized inputs (M < 8, e.g. batch-1 serving) get bm = M: padding
    # them to a fixed block would run the whole forest on thrown-away
    # columns every call
    bm = bm or (128 if m >= 128 else min(8, m))
    pad = (-m) % bm
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    out = transitive_forest_pallas(
        x, dplan.level_src, dplan.level_xsrc,
        dplan.direct_idx, dplan.direct_x_idx, dplan.direct_bits,
        dplan.gather_idx, dplan.signs, t=dplan.t, groups=dplan.groups,
        n=dplan.n, k=dplan.k, bm=bm, interpret=interpret)
    out = out[:, :m].reshape(dplan.n, dplan.groups, m)
    return out[:, 0] if dplan.groups == 1 else out
