"""Pallas TPU kernels for the performance-critical GEMM/scan hot spots.

  transitive_gemm — the paper's result-reuse dataflow (split-LUT doubling),
                    multiplication-free, VPU-oriented (ASIC-faithful).
  w4a8_gemm       — fused group-dequant int8 MXU GEMM (TPU-native hot path).
  rg_lru          — blocked linear-recurrence scan for recurrent archs.

Each kernel has a pure-jnp oracle in ref.py and is validated in interpret
mode across shape/dtype sweeps (tests/test_kernels.py).
"""
from repro.kernels import ops, ref  # noqa: F401
