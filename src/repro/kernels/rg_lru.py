"""Pallas TPU kernel: blocked linear recurrence (RG-LRU / SSM scan).

h_t = a_t * h_{t-1} + x_t, computed per sequence block with an in-block
doubling (Blelloch-style) scan — log2(bs) shifted multiply-adds on the VPU —
and a VMEM carry across blocks. The sequence grid axis is sequential
("arbitrary"); batch and feature axes are parallel.

This serves the long_500k decode/prefill path of the recurrent archs
(recurrentgemma, xlstm), where attention-free state makes 500k context
sub-quadratic (DESIGN.md §5).

VMEM per step (bb=8, bs=256, bd=256): 3 blocks x 8x256x256 f32 = 6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["rg_lru_pallas"]


def _kernel(x_ref, a_ref, h0_ref, out_ref, carry_ref, *, bs):
    sk = pl.program_id(2)

    @pl.when(sk == 0)
    def _init():
        carry_ref[...] = h0_ref[...].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)      # (bb, bs, bd)
    h = x_ref[...].astype(jnp.float32)
    # In-block inclusive scan by doubling: after step o,
    # h_t = sum_{t-2o < u <= t} (prod a) x_u, a_t = prod of 2o coefficients.
    off = 1
    while off < bs:
        h_shift = jnp.pad(h, ((0, 0), (off, 0), (0, 0)))[:, :bs, :]
        a_shift = jnp.pad(a, ((0, 0), (off, 0), (0, 0)),
                          constant_values=1.0)[:, :bs, :]
        h = h + a * h_shift
        a = a * a_shift
        off *= 2
    h = h + a * carry_ref[...][:, None, :]
    out_ref[...] = h.astype(out_ref.dtype)
    carry_ref[...] = h[:, -1, :]


@functools.partial(jax.jit, static_argnames=("bb", "bs", "bd", "interpret"))
def rg_lru_pallas(x: jnp.ndarray, a: jnp.ndarray, h0: jnp.ndarray, *,
                  bb: int = 8, bs: int = 256, bd: int = 256,
                  interpret: bool = True) -> jnp.ndarray:
    """x, a: (B, S, D); h0: (B, D) -> h: (B, S, D)."""
    b, s, d = x.shape
    bb, bs, bd = min(bb, b), min(bs, s), min(bd, d)
    assert b % bb == 0 and s % bs == 0 and d % bd == 0, (x.shape, bb, bs, bd)
    grid = (b // bb, d // bd, s // bs)      # sequence axis last → sequential
    return pl.pallas_call(
        functools.partial(_kernel, bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bs, bd), lambda i, j, sk: (i, sk, j)),
            pl.BlockSpec((bb, bs, bd), lambda i, j, sk: (i, sk, j)),
            pl.BlockSpec((bb, bd), lambda i, j, sk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bb, bs, bd), lambda i, j, sk: (i, sk, j)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bb, bd), jnp.float32)],
        interpret=interpret,
    )(x, a, h0)
