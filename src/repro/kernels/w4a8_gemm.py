"""Pallas TPU kernel: fused group-dequant W4/W8 x A8 GEMM (MXU hot path).

This is the TPU-native side of the hardware adaptation (DESIGN.md §2): on
TPU the technique's *memory* win (4-bit weights → half the HBM traffic for
decode-bound GEMMs) is what reaches roofline, while the adder-reuse win is
ASIC-specific. The kernel keeps weights quantized in VMEM, runs the int8
MXU dot per quantization group, and applies the per-group scales in the
f32 epilogue — the paper's Sec. 4.5 "integer scale per 128/T tile" folded
into the matmul.

Tiling (defaults bm=128, bn=128, bk=512, group=128):
  x block 128x512 i8 = 64 KiB; w block 128x512 i8 = 64 KiB;
  sg block 128x4 f32; acc/out 128x128 f32 = 64 KiB  → VMEM-friendly,
  MXU dims all multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["w4a8_gemm_pallas"]


def _kernel(x_ref, w_ref, sg_ref, sx_ref, out_ref, *, bk, group, nk):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    acc = jnp.zeros(out_ref.shape, jnp.float32)
    for gi in range(bk // group):
        xs = x_ref[:, gi * group:(gi + 1) * group]
        ws = w_ref[:, gi * group:(gi + 1) * group]
        part = jax.lax.dot_general(
            xs, ws, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)              # (bm, bn) MXU int8
        acc = acc + part.astype(jnp.float32) * sg_ref[:, gi][None, :]
    out_ref[...] += acc

    @pl.when(kk == nk - 1)
    def _epilogue():
        out_ref[...] *= sx_ref[...]


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "bk",
                                             "interpret"))
def w4a8_gemm_pallas(qx: jnp.ndarray, sx: jnp.ndarray, qw: jnp.ndarray,
                     sg: jnp.ndarray, *, group: int = 128,
                     bm: int = 128, bn: int = 128, bk: int = 512,
                     interpret: bool = True) -> jnp.ndarray:
    """f32 (M, N) = dequant(qw, sg) @ qx^T-style fused GEMM.

    qx (M, K) i8, sx (M, 1) f32 per-token scales,
    qw (N, K) i8 (int4 values stored in i8 for W4), sg (N, K//group) f32.
    """
    m, k = qx.shape
    n = qw.shape[0]
    bk = min(bk, k)
    assert k % bk == 0 and bk % group == 0, (k, bk, group)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    assert sg.shape == (n, k // group)
    nk = k // bk
    gpb = bk // group
    return pl.pallas_call(
        functools.partial(_kernel, bk=bk, group=group, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bn, gpb), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(qx, qw, sg, sx)
