"""Pallas TPU kernel: transitive-reuse (multiplication-free) quantized GEMM.

The faithful TPU mapping of the paper's dataflow (DESIGN.md §2): per T-wide
k-subtile we build the *complete* subset-sum LUT by doubling — every Hasse
node's prefix is its pattern with the lowest set bit cleared, so every
reuse step has distance 1 and the schedule is data-independent. Weight
TransRows (packed outside the kernel) gather their subset sum from the LUT
and shift-accumulate across bit planes with 2's-complement signs.

Beyond-paper optimisation: **split-LUT** — for T=8 we keep two 4-bit LUTs
(hi/lo nibble) instead of one 256-entry LUT: 30 build-adds instead of 255
and a 32x smaller VMEM table, at +1 add per gather (hierarchical transitive
reuse; a DSE point the paper did not explore).

VMEM budget per grid step (defaults bm=128, bn=64, bk=256, T=8, S=8):
  x block   128x256 i8           = 32 KiB
  rows      64*8 x 32 i32        = 64 KiB
  LUT       2 x (128x16) i32     = 16 KiB
  out block 128x64 i32           = 32 KiB            → well under 16 MiB VMEM.
MXU note: the gather is VPU-side; on MXU silicon the one-hot formulation of
a gather costs >= the dense int8 dot, so this kernel is the *adder-optimal*
dataflow (ASIC-faithful), while kernels/w4a8_gemm.py is the MXU-optimal one.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import bitslice

__all__ = ["transitive_gemm_pallas"]


def _lut4(xt: jnp.ndarray) -> jnp.ndarray:
    """(bm, 4) int32 -> (bm, 16) subset sums via 4 doubling steps."""
    lut = jnp.zeros(xt.shape[:-1] + (1,), jnp.int32)
    for b in range(4):
        lut = jnp.concatenate([lut, lut + xt[:, b:b + 1]], axis=-1)
    return lut


def _lut_full(xt: jnp.ndarray, t: int) -> jnp.ndarray:
    lut = jnp.zeros(xt.shape[:-1] + (1,), jnp.int32)
    for b in range(t):
        lut = jnp.concatenate([lut, lut + xt[:, b:b + 1]], axis=-1)
    return lut


def _kernel(x_ref, rows_ref, out_ref, *, t, w_bits, bk, split_lut):
    bm = x_ref.shape[0]
    bn = rows_ref.shape[0]
    s = w_bits
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)
    # 2's-complement plane weights as python scalars (no captured consts)
    signs = [(-1 if b == s - 1 else 1) * (1 << b) for b in range(s)]
    acc = jnp.zeros((bm, bn), jnp.int32)
    for j in range(bk // t):                              # static unroll
        xt = x[:, j * t:(j + 1) * t]
        p = rows_ref[:, :, j].reshape(bn * s)             # (bn*S,) patterns
        if split_lut and t == 8:
            lo = _lut4(xt[:, :4])
            hi = _lut4(xt[:, 4:])
            g = jnp.take(lo, p & 15, axis=1) + jnp.take(hi, p >> 4, axis=1)
        else:
            lut = _lut_full(xt, t)
            g = jnp.take(lut, p, axis=1)                  # (bm, bn*S)
        gr = g.reshape(bm, bn, s)
        for b in range(s):                                # shift-accumulate
            acc = acc + signs[b] * gr[:, :, b]
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("w_bits", "t", "bm", "bn", "bk",
                                             "split_lut", "interpret"))
def transitive_gemm_pallas(qx: jnp.ndarray, qw: jnp.ndarray, *,
                           w_bits: int = 8, t: int = 8,
                           bm: int = 128, bn: int = 64, bk: int = 256,
                           split_lut: bool = True,
                           interpret: bool = True) -> jnp.ndarray:
    """int32 [qx (M, K) i8] @ [qw (N, K) i8]^T with transitive reuse.

    M, N, K must be divisible by (bm, bn, bk); ops.py handles padding.
    """
    m, k = qx.shape
    n = qw.shape[0]
    if qw.shape[1] != k:
        raise ValueError(f"reduction mismatch: qx {qx.shape} vs qw {qw.shape}")
    if k % bk or bk % t:
        raise ValueError(f"K={k} must tile by bk={bk} and bk by T={t}")
    if m % bm or n % bn:
        raise ValueError(f"M={m}, N={n} must tile by bm={bm}, bn={bn} "
                         "(kernels/ops.py pads non-divisible shapes)")
    # Pre-pack TransRows (offline in the paper; cheap jnp here).
    planes = bitslice.bit_planes_jnp(qw.astype(jnp.int32), w_bits)
    rows = bitslice.pack_transrows_jnp(planes, t)          # (S, N, J)
    rows = jnp.moveaxis(rows, 0, 1).astype(jnp.int32)      # (N, S, J)

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_kernel, t=t, w_bits=w_bits, bk=bk,
                          split_lut=split_lut),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, w_bits, bk // t), lambda i, j, kk: (j, 0, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.int32),
        interpret=interpret,
    )(qx, rows)
