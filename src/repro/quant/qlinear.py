"""TransitiveLinear — the paper's technique as a first-class linear layer.

Three operating modes:
  * ``none`` — plain dense matmul in the working dtype (FP baseline).
  * ``qat``  — fake-quantized weights (straight-through), for training the
               models that will later serve through the Transitive Array.
  * ``ptq``  — weights stored as integers + scales; activations quantized
               per-token at runtime; the integer GEMM runs through one of:
      - ``int_dot``: dense int8 dot_general (int32 accumulation). The
        MXU-native execution used by the full-scale dry-run.
      - ``lut``:     pure-jnp dense doubling-LUT transitive execution
                     (kernels/ref.py) — bit-exact with int_dot, the paper's
                     result-reuse dataflow in software.
      - ``pallas``:  the Pallas TPU kernel (kernels/transitive_gemm.py);
                     interpret mode on CPU.
      - ``engine``:  the batched multi-tile scoreboard engine
                     (core/engine.py) on the host via pure_callback — the
                     faithful Scoreboard-forest dataflow, bit-exact with
                     int_dot. Kept as the oracle alongside transitive_ref.
      - ``engine_jit``: the same planned forest executed **device-resident**
                     (core/engine.py DevicePlan + run_device): pure jnp
                     gathers/scatters under jit, zero host callbacks. Plans
                     come from the process plan cache at trace time when the
                     weight is concrete, or from a ``"dplan"`` embedded in
                     the params (plancache.attach_device_plans) when the
                     weight is a tracer — e.g. inside the model's block
                     scan.
      - ``engine_pallas``: the DevicePlan forest as a Pallas kernel
                     (kernels/transitive_forest.py; interpret on CPU).

All paths share the same quantization, so they agree bit-exactly on the
int32 accumulator (property-tested).

Layers are functional: ``linear_init`` builds a params dict,
``linear_apply`` consumes it. Weight layout is (d_out, d_in) so the
reduction axis is last (TransRows slice along it).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

import repro.quant.quantize as Q

__all__ = ["QuantConfig", "linear_init", "linear_apply"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "none"        # none | qat | ptq
    w_bits: int = 8
    a_bits: int = 8
    group: int = 128          # group size along d_in (exact paths / qat)
    # int_dot | lut | pallas | engine | engine_jit | engine_pallas
    path: str = "int_dot"
    transrow_t: int = 8       # TransRow width for transitive paths

    def with_(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


def _effective_group(cfg: QuantConfig, d_in: int) -> int:
    g = cfg.group
    if g <= 0 or d_in % g:
        return d_in               # fall back to per-channel
    return g


def linear_init(key: jax.Array, d_in: int, d_out: int,
                cfg: QuantConfig = QuantConfig(),
                dtype=jnp.bfloat16) -> dict[str, Any]:
    scale = 1.0 / (d_in ** 0.5)
    w = jax.random.normal(key, (d_out, d_in), jnp.float32) * scale
    if cfg.mode != "ptq":
        return {"w": w.astype(dtype)}
    g = _effective_group(cfg, d_in)
    qw, sg = Q.quantize_groupwise(w, cfg.w_bits, g)
    return {"qw": qw, "sg": sg.astype(jnp.float32)}


def _int_matmul(qx: jnp.ndarray, qw: jnp.ndarray) -> jnp.ndarray:
    """int8 (..., K) x int8 (N, K) -> int32 (..., N)."""
    return jax.lax.dot_general(
        qx, qw, (((qx.ndim - 1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


def _engine_matmul(qx: jnp.ndarray, qw: jnp.ndarray, w_bits: int,
                   t: int) -> jnp.ndarray:
    """Batched transitive engine (host numpy) as a jit-safe integer GEMM.

    The hot path is run-only: the weight-side plan comes from the
    process-level plan cache (core/plancache.py), so planning happens once
    per distinct quantized weight, not once per forward call."""
    import numpy as np
    from repro.core import plancache

    out = jax.ShapeDtypeStruct(qx.shape[:-1] + (qw.shape[0],), jnp.int32)

    def host(qx_np, qw_np):
        # shape-agnostic: under vmap the callback sees extra leading axes
        # (size-1 on the unmapped weight with vmap_method="expand_dims").
        qw2 = np.asarray(qw_np).reshape(qw_np.shape[-2:])
        flat = np.asarray(qx_np, np.int64).reshape(-1, qx_np.shape[-1])
        y = plancache.default_cache().run(qw2, flat.T, w_bits, t).T
        return (y.reshape(qx_np.shape[:-1] + (qw2.shape[0],))
                .astype(np.int32))

    from repro import jax_compat
    return jax_compat.pure_callback(host, out, qx, qw,
                                    vmap_method="expand_dims")


def _engine_matmul_grouped(xg: jnp.ndarray, wg: jnp.ndarray, w_bits: int,
                           t: int) -> jnp.ndarray:
    """Grouped engine GEMM: xg (..., G, g) x wg (N, G, g) -> (..., G, N).

    All ``G`` groups execute as *one* cached plan with a batched tile axis
    (engine ``groups=G``) — one host round trip, one scoreboard build, no
    per-group Python loop."""
    import numpy as np
    from repro.core import plancache

    n, n_groups, g = wg.shape
    out = jax.ShapeDtypeStruct(xg.shape[:-1] + (n,), jnp.int32)

    def host(xg_np, wg_np):
        qw2 = np.asarray(wg_np).reshape(wg_np.shape[-3], n_groups * g)
        flat = np.asarray(xg_np, np.int64).reshape(-1, n_groups * g)
        part = plancache.default_cache().run(qw2, flat.T, w_bits, t,
                                             groups=n_groups)   # (N, G, M)
        return (part.transpose(2, 1, 0)
                .reshape(xg_np.shape[:-1] + (n,)).astype(np.int32))

    from repro import jax_compat
    return jax_compat.pure_callback(host, out, xg, wg,
                                    vmap_method="expand_dims")


def _device_plan(params, qw: jnp.ndarray, w_bits: int, t: int, groups: int):
    """Resolve the DevicePlan for the engine_jit / engine_pallas paths.

    Preference order: a ``"dplan"`` embedded in the params (survives jit /
    vmap / scan — the weight may be a tracer there), else a trace-time
    process-cache lookup, which needs the weight concrete."""
    dplan = params.get("dplan")
    if dplan is not None:
        # consistency of everything checkable under trace. Weight CONTENT
        # cannot be checked here (qw may be a tracer): an embedded plan is
        # only as fresh as the last attach_device_plans — re-attach after
        # any weight update, or the old weights' GEMM comes back silently.
        sig = (dplan.bits, dplan.t, dplan.n, dplan.k, dplan.groups)
        want = (w_bits, t, qw.shape[-2], qw.shape[-1], groups)
        if sig != want:
            raise ValueError(
                f"attached plan signature (bits, t, n, k, groups)={sig} "
                f"does not match the layer's {want} — re-attach with the "
                f"serving QuantConfig")
        return dplan
    if isinstance(qw, jax.core.Tracer):
        raise ValueError(
            "path='engine_jit'/'engine_pallas' saw a traced weight with no "
            "attached plan: embed plans with "
            "plancache.attach_device_plans(params, cfg) (or "
            "Model.attach_device_plans) before jit, or close the params "
            "over the jit. path='engine' (host callback) also handles "
            "traced weights.")
    import numpy as np
    from repro.core import plancache
    return plancache.default_cache().get_or_build_device(
        np.asarray(qw), w_bits, t, groups)


def _run_dplan(dplan, flat: jnp.ndarray, path: str) -> jnp.ndarray:
    """Shared backend dispatch: flat (K, B) activations through the plan."""
    if path == "engine_pallas":
        from repro.kernels import transitive_forest
        return transitive_forest.transitive_forest(dplan, flat)
    from repro.core import engine
    return engine.run_device_jit(dplan, flat)


def _engine_matmul_device(qx: jnp.ndarray, dplan, path: str) -> jnp.ndarray:
    """Device-resident forest GEMM: qx (..., K) -> int32 (..., N).

    Pure JAX end to end — the lowered jaxpr contains no pure_callback."""
    flat = qx.reshape(-1, qx.shape[-1]).astype(jnp.int32).T    # (K, B)
    y = _run_dplan(dplan, flat, path)                          # (N, B)
    return y.T.reshape(qx.shape[:-1] + (dplan.n,))


def _engine_matmul_device_grouped(xg: jnp.ndarray, dplan,
                                  path: str) -> jnp.ndarray:
    """Grouped device forest: xg (..., G, g) -> int32 (..., G, N)."""
    n_groups, g = xg.shape[-2], xg.shape[-1]
    flat = xg.reshape(-1, n_groups * g).astype(jnp.int32).T
    y = _run_dplan(dplan, flat, path)                          # (N, G, B)
    return y.transpose(2, 1, 0).reshape(xg.shape[:-1] + (dplan.n,))


def _ptq_apply(params, x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    qw, sg = params["qw"], params["sg"]
    d_out, d_in = qw.shape
    g = d_in // sg.shape[-1]
    qx, sx = Q.quantize_per_token(x, cfg.a_bits)
    if sg.shape[-1] == 1:
        # per-channel: one dense int GEMM + epilogue scale
        if cfg.path == "lut":
            from repro.kernels import ref
            y32 = ref.transitive_matmul_ref(qx, qw, cfg.w_bits, cfg.transrow_t)
        elif cfg.path == "pallas":
            from repro.kernels import ops
            y32 = ops.transitive_gemm(qx, qw, w_bits=cfg.w_bits,
                                      t=cfg.transrow_t)
        elif cfg.path == "engine":
            y32 = _engine_matmul(qx, qw, cfg.w_bits, cfg.transrow_t)
        elif cfg.path in ("engine_jit", "engine_pallas"):
            dplan = _device_plan(params, qw, cfg.w_bits, cfg.transrow_t, 1)
            y32 = _engine_matmul_device(qx, dplan, cfg.path)
        else:
            y32 = _int_matmul(qx, qw)
        y = y32.astype(jnp.float32) * sx * sg[:, 0]
    else:
        # group-wise: per-group int partials rescaled in the epilogue —
        # the VPU "integer scale factor per 128/T tile" of Sec. 4.5.
        xg = qx.reshape(qx.shape[:-1] + (d_in // g, g))
        wg = qw.reshape(d_out, d_in // g, g)
        if cfg.path == "lut":
            from repro.kernels import ref
            part = ref.transitive_matmul_grouped_ref(xg, wg, cfg.w_bits,
                                                     cfg.transrow_t)
        elif cfg.path == "pallas":
            from repro.kernels import ops
            part = ops.transitive_gemm_grouped(xg, wg, w_bits=cfg.w_bits,
                                               t=cfg.transrow_t)
        elif cfg.path == "engine":
            part = _engine_matmul_grouped(xg, wg, cfg.w_bits, cfg.transrow_t)
        elif cfg.path in ("engine_jit", "engine_pallas"):
            dplan = _device_plan(params, qw, cfg.w_bits, cfg.transrow_t,
                                 d_in // g)
            part = _engine_matmul_device_grouped(xg, dplan, cfg.path)
        else:
            part = jnp.einsum("...gi,ngi->...gn", xg, wg,
                              preferred_element_type=jnp.int32)
        y = jnp.einsum("...gn,ng->...n", part.astype(jnp.float32), sg) * sx
    return y.astype(x.dtype)


def linear_apply(params: dict[str, Any], x: jnp.ndarray,
                 cfg: QuantConfig = QuantConfig()) -> jnp.ndarray:
    """y = x @ W^T under the configured quantization mode."""
    if cfg.mode == "ptq":
        return _ptq_apply(params, x, cfg)
    w = params["w"]
    if cfg.mode == "qat":
        g = _effective_group(cfg, w.shape[-1])
        w = Q.fake_quant(w, cfg.w_bits, g)
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())))
