"""TransitiveLinear — the paper's technique as a first-class linear layer.

Three operating modes:
  * ``none`` — plain dense matmul in the working dtype (FP baseline).
  * ``qat``  — fake-quantized weights (straight-through), for training the
               models that will later serve through the Transitive Array.
  * ``ptq``  — weights stored as integers + scales; activations quantized
               per-token at runtime; the integer GEMM routes through a
               **registered execution backend** (core/backend.py):
               ``int_dot`` (dense MXU int GEMM), ``lut`` / ``pallas`` (the
               doubling-LUT dataflow, jnp / Pallas kernel), ``engine``
               (host Scoreboard forest via pure_callback — the oracle),
               ``engine_jit`` / ``engine_pallas`` (the planned forest
               device-resident, zero host callbacks). Any backend
               registered via ``repro.core.backend.register_backend``
               is selectable by name — there is no string dispatch here.

All backends share the same quantization, so they agree bit-exactly on the
int32 accumulator (property-tested over ``list_backends()``).

Layers are functional: ``linear_init`` builds a params dict,
``linear_apply`` consumes it. Weight layout is (d_out, d_in) so the
reduction axis is last (TransRows slice along it).

``QuantConfig.backend`` names the registry backend; the legacy
``QuantConfig(path=...)`` spelling still resolves through the same registry
but emits a ``DeprecationWarning``.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any

import jax
import jax.numpy as jnp

import repro.quant.quantize as Q
from repro.core.backend import EngineConfig, get_backend, list_backends

__all__ = ["QuantConfig", "linear_init", "linear_apply"]


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    mode: str = "none"        # none | qat | ptq
    w_bits: int = 8
    a_bits: int = 8
    group: int = 128          # group size along d_in (exact paths / qat)
    # integer-GEMM execution backend — any repro.core.backend registry name
    backend: str = "int_dot"
    # DEPRECATED alias for ``backend``; resolves via the shim below
    path: str | None = None
    transrow_t: int = 8       # TransRow width for transitive backends

    def with_(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)

    def backend_name(self) -> str:
        """The registry backend this config serves through.

        Legacy ``path=`` strings take precedence (existing configs keep
        their meaning) but warn: the strings were ad-hoc; the registry is
        the API."""
        if self.path is not None:
            warnings.warn(
                "QuantConfig(path=...) is deprecated; use backend=... — "
                "names resolve through repro.core.backend.get_backend",
                DeprecationWarning, stacklevel=2)
            return self.path
        return self.backend


def _effective_group(cfg: QuantConfig, d_in: int) -> int:
    g = cfg.group
    if g <= 0 or d_in % g:
        return d_in               # fall back to per-channel
    return g


def linear_init(key: jax.Array, d_in: int, d_out: int,
                cfg: QuantConfig = QuantConfig(),
                dtype=jnp.bfloat16) -> dict[str, Any]:
    scale = 1.0 / (d_in ** 0.5)
    w = jax.random.normal(key, (d_out, d_in), jnp.float32) * scale
    if cfg.mode != "ptq":
        return {"w": w.astype(dtype)}
    g = _effective_group(cfg, d_in)
    qw, sg = Q.quantize_groupwise(w, cfg.w_bits, g)
    return {"qw": qw, "sg": sg.astype(jnp.float32)}


def _resolve_device_plan(params, backend, qw: jnp.ndarray,
                         ecfg: EngineConfig):
    """Resolve the DevicePlan a device-resident planned backend executes.

    Preference order: a ``"dplan"`` embedded in the params (survives jit /
    vmap / scan — the weight may be a tracer there), else a trace-time
    process-cache lookup, which needs the weight concrete. Backends that
    do not consume device plans resolve to None."""
    if not (backend.needs_plan and backend.device_resident):
        return None
    dplan = params.get("dplan")
    if dplan is not None:
        # consistency of everything checkable under trace. Weight CONTENT
        # cannot be checked here (qw may be a tracer): an embedded plan is
        # only as fresh as the last attach_device_plans — re-attach after
        # any weight update, or the old weights' GEMM comes back silently.
        # Custom backends with their own lowering layout validate inside
        # their execute(); only the standard DevicePlan schema is checked
        # here.
        from repro.core.engine import DevicePlan
        if isinstance(dplan, DevicePlan):
            sig = (dplan.bits, dplan.t, dplan.n, dplan.k, dplan.groups)
            want = (ecfg.w_bits, ecfg.t, qw.shape[-2], qw.shape[-1],
                    ecfg.groups)
            if sig != want:
                raise ValueError(
                    f"attached plan signature (bits, t, n, k, groups)="
                    f"{sig} does not match the layer's {want} — re-attach "
                    f"with the serving QuantConfig")
        return dplan
    if isinstance(qw, jax.core.Tracer):
        fallback = ", ".join(
            n for n in list_backends()
            if not (get_backend(n).needs_plan
                    and get_backend(n).device_resident))
        raise ValueError(
            f"backend '{backend.name}' is device-resident and saw a traced "
            f"weight with no attached DevicePlan. Remedy: embed plans with "
            f"plancache.attach_device_plans(params, cfg) (or "
            f"Model.attach_device_plans) before jit, or close concrete "
            f"params over the jit. Registered backends that handle traced "
            f"weights without attachment: {fallback}.")
    import numpy as np
    from repro.core import plancache
    return plancache.default_cache().get_or_build_device(
        np.asarray(qw), ecfg, backend=backend.name)


def _resolve_plan(backend, qw: jnp.ndarray, ecfg: EngineConfig, dplan):
    """Resolve the host ExecutionPlan for a ``needs_plan`` backend.

    A device plan supersedes it; a traced weight cannot be planned here
    (host backends then resolve plans themselves — the built-in engine
    looks the plan up in the process cache inside its callback)."""
    if not backend.needs_plan or dplan is not None:
        return None
    if isinstance(qw, jax.core.Tracer):
        return None
    import numpy as np
    return backend.plan(np.asarray(qw), ecfg)


def _ptq_apply(params, x: jnp.ndarray, cfg: QuantConfig) -> jnp.ndarray:
    backend = get_backend(cfg.backend_name())
    qw, sg = params["qw"], params["sg"]
    d_out, d_in = qw.shape
    if d_in % sg.shape[-1]:
        # a floor-divided group size would reshape into the wrong groups
        # and silently mis-scale every output channel
        raise ValueError(
            f"grouped PTQ layer mis-shaped: weight ({d_out}, {d_in}) "
            f"carries {sg.shape[-1]} scale groups, but d_in={d_in} is not "
            f"divisible by the group count — requantize with a group size "
            f"that divides d_in")
    g = d_in // sg.shape[-1]
    qx, sx = Q.quantize_per_token(x, cfg.a_bits)
    if sg.shape[-1] == 1:
        # per-channel: one dense int GEMM + epilogue scale
        ecfg = EngineConfig.from_quant(cfg, groups=1)
        dplan = _resolve_device_plan(params, backend, qw, ecfg)
        plan = _resolve_plan(backend, qw, ecfg, dplan)
        y32 = backend.execute(qx, qw, plan, dplan, ecfg)
        y = y32.astype(jnp.float32) * sx * sg[:, 0]
    else:
        # group-wise: per-group int partials rescaled in the epilogue —
        # the VPU "integer scale factor per 128/T tile" of Sec. 4.5.
        n_groups = d_in // g
        if not backend.supports_groups:
            raise ValueError(
                f"backend '{backend.name}' does not support group-wise "
                f"quantization (supports_groups=False); use group=0 "
                f"(per-channel) or a grouped backend")
        ecfg = EngineConfig.from_quant(cfg, groups=n_groups)
        dplan = _resolve_device_plan(params, backend, qw, ecfg)
        plan = _resolve_plan(backend, qw, ecfg, dplan)   # from 2-D qw
        xg = qx.reshape(qx.shape[:-1] + (n_groups, g))
        wg = qw.reshape(d_out, n_groups, g)
        part = backend.execute(xg, wg, plan, dplan, ecfg)   # (..., G, N)
        y = jnp.einsum("...gn,ng->...n", part.astype(jnp.float32), sg) * sx
    return y.astype(x.dtype)


def linear_apply(params: dict[str, Any], x: jnp.ndarray,
                 cfg: QuantConfig = QuantConfig()) -> jnp.ndarray:
    """y = x @ W^T under the configured quantization mode."""
    if cfg.mode == "ptq":
        return _ptq_apply(params, x, cfg)
    w = params["w"]
    if cfg.mode == "qat":
        g = _effective_group(cfg, w.shape[-1])
        w = Q.fake_quant(w, cfg.w_bits, g)
    return jax.lax.dot_general(
        x, w.astype(x.dtype), (((x.ndim - 1,), (1,)), ((), ())))
