"""Quantization substrate: group-wise symmetric PTQ/QAT + TransitiveLinear.

Note: the ``quantize`` *module* holds the raw quantizers; only collision-free
names are re-exported here.
"""
from repro.quant.quantize import (  # noqa: F401
    absmax_scale, quantize_groupwise, dequantize_groupwise, fake_quant,
    quantize_per_token)
from repro.quant.qlinear import QuantConfig, linear_init, linear_apply  # noqa: F401
