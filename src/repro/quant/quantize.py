"""Symmetric integer quantization (per-tensor / per-token / group-wise).

Matches the paper's evaluation setup (Sec. 4.5/5.4): group-wise weight
quantization with group size 128 (following QServe), per-token dynamic
activation quantization, scales in fp32. All quantizers are jit-safe.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["absmax_scale", "quantize", "dequantize", "quantize_groupwise",
           "dequantize_groupwise", "quantize_per_token", "fake_quant"]


def _qmax(bits: int) -> int:
    return (1 << (bits - 1)) - 1


def absmax_scale(x: jnp.ndarray, bits: int, axis=None) -> jnp.ndarray:
    """Symmetric absmax scale; keeps reduced dims."""
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-8) / _qmax(bits)


def quantize(x: jnp.ndarray, bits: int, scale: jnp.ndarray) -> jnp.ndarray:
    q = jnp.round(x / scale)
    return jnp.clip(q, -_qmax(bits) - 1, _qmax(bits)).astype(jnp.int8)


def dequantize(q: jnp.ndarray, scale: jnp.ndarray,
               dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_groupwise(w: jnp.ndarray, bits: int, group: int = 128):
    """Quantize ``w (..., K)`` with one scale per ``group`` along K.

    Returns (q int8 (..., K), scales f32 (..., K//group)).
    """
    k = w.shape[-1]
    if k % group:
        raise ValueError(f"K={k} not divisible by group={group}")
    wg = w.reshape(w.shape[:-1] + (k // group, group))
    scale = absmax_scale(wg, bits, axis=-1)            # (..., K//g, 1)
    q = quantize(wg, bits, scale)
    return q.reshape(w.shape), scale[..., 0]


def dequantize_groupwise(q: jnp.ndarray, scales: jnp.ndarray, group: int,
                         dtype=jnp.float32) -> jnp.ndarray:
    k = q.shape[-1]
    qg = q.reshape(q.shape[:-1] + (k // group, group))
    w = qg.astype(jnp.float32) * scales[..., None]
    return w.reshape(q.shape).astype(dtype)


def quantize_per_token(x: jnp.ndarray, bits: int = 8):
    """Dynamic per-token activation quantization over the last axis."""
    scale = absmax_scale(x, bits, axis=-1)             # (..., 1)
    return quantize(x, bits, scale), scale


@jax.custom_vjp
def fake_quant(x: jnp.ndarray, bits: int, group: int):
    q, s = quantize_groupwise(x, bits, group)
    return dequantize_groupwise(q, s, group, x.dtype)


def _fq_fwd(x, bits, group):
    return fake_quant(x, bits, group), None


def _fq_bwd(_, g):
    return (g, None, None)          # straight-through estimator


fake_quant.defvjp(_fq_fwd, _fq_bwd)
