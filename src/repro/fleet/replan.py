"""Asynchronous re-planning for live-weight serving.

Transitive Array's execution plans are derived from the *weight
bit-patterns* (the transitive DAG over weight rows), so unlike
plain-GEMM serving, every weight update invalidates the whole plan
forest. This module keeps that cost off the serving hot path:

  * :func:`build_generation` — the offline half for ONE set of weights:
    plan (through the :class:`~repro.core.plancache.PlanCache`, reusing
    its ``_Pending`` single-build coalescing), compile + attach
    ``DevicePlan``s, align their pads against the currently-serving
    generation (:func:`align_device_plans`) and mesh-place them. Pure
    function of its inputs; safe to run on any thread.
  * :class:`ReplanWorker` — a background thread that runs
    ``build_generation`` on submitted weights, newest-submission-wins,
    and hands finished generations to a callback (typically
    ``ServeEngine.swap_params``). A failed build never reaches the
    engine: the previous generation keeps serving — that IS the
    rollback.
  * :class:`WeightWatcher` — polls a checkpoint directory
    (``repro.distributed.checkpoint`` format) and feeds new weights to
    the worker; the serve loop calls ``poll()`` between host steps.

The pad-alignment detail is what makes hot swaps retrace-free: a
``DevicePlan``'s direct-dispatch width ``D`` is a function of weight
*content*, so two generations of the same layer lower to different leaf
shapes unless the later one is padded (bit-exactly — pad lanes are
dropped scatters) to at least the earlier one's width. With aligned
avals the serve engine's memoised decode jit is hit, not retraced.
"""
from __future__ import annotations

import dataclasses
import hashlib
import threading
import time
from typing import Any, Callable

import numpy as np

from repro.core import plancache
from repro.core.backend import get_backend, shard_device_plan
from repro.core.engine import DevicePlan, pad_device_plan

__all__ = ["Generation", "ReplanSuperseded", "ReplanTicket",
           "ReplanWorker", "WeightWatcher", "align_device_plans",
           "build_generation", "fingerprint_params"]


def fingerprint_params(params: Any) -> str:
    """Content hash of a whole params tree's weights.

    Hashes every quantized-weight (``qw``) leaf when the tree has them
    (the plans only depend on those), else every array leaf — in
    deterministic walk order, shape+dtype included. This is the
    generation identity the fleet coalesces and refuses on: same
    fingerprint ⇒ same plans.
    """
    import jax

    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    qw = [(p, a) for p, a in leaves
          if any(getattr(k, "key", None) == "qw" for k in p)]
    h = hashlib.blake2b(digest_size=16)
    for path, leaf in (qw or leaves):
        if isinstance(leaf, DevicePlan):
            continue               # derived from qw; not weight content
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(repr((jax.tree_util.keystr(path),
                       a.shape, a.dtype.str)).encode())
        h.update(a.tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class Generation:
    """One fully-built weight generation, ready to attach to an engine."""
    gen: int
    params: Any                # dplans embedded + mesh-placed (if planned)
    fingerprint: str           # fingerprint_params of the input weights
    tag: Any = None            # caller's label (checkpoint step, ...)
    build_s: float = 0.0       # wall seconds build_generation spent
    plans_built: int = 0       # cold plan builds (cache misses) it caused


def _round_pad(n: int) -> int:
    """Next power of two >= n (>= 8): headroom so the direct width of
    the *next* generation likely fits without growing the aval again."""
    b = 8
    while b < n:
        b *= 2
    return b


def _walk_dplans(tree: Any, ref: Any, fn: Callable) -> Any:
    """Rebuild ``tree`` with ``fn(dplan, ref_dplan_or_None)`` applied to
    every embedded standard DevicePlan (custom layouts pass through)."""
    if isinstance(tree, dict):
        out = {k: _walk_dplans(v,
                               ref.get(k) if isinstance(ref, dict) else None,
                               fn)
               for k, v in tree.items()}
        if isinstance(tree.get("dplan"), DevicePlan):
            r = ref.get("dplan") if isinstance(ref, dict) else None
            out["dplan"] = fn(tree["dplan"],
                              r if isinstance(r, DevicePlan) else None)
        return out
    if isinstance(tree, list):
        ref = ref if isinstance(ref, list) else [None] * len(tree)
        return [_walk_dplans(v, r, fn) for v, r in zip(tree, ref)]
    if isinstance(tree, tuple):
        ref = ref if isinstance(ref, tuple) else (None,) * len(tree)
        return tuple(_walk_dplans(v, r, fn) for v, r in zip(tree, ref))
    return tree


def align_device_plans(params: Any, ref_params: Any | None) -> Any:
    """Pad ``params``' embedded DevicePlans so their leaf avals match
    ``ref_params``' (the currently-serving generation).

    The direct-dispatch width is the ONE DevicePlan dimension that
    depends on weight content; everything else is signature-shaped.
    Where the new plan's width already fits under the reference's, it is
    padded to *exactly* the reference width — identical avals, decode
    jit cache hit, zero retrace on swap. Where it outgrew the reference,
    it is padded up to a power-of-two bound instead (one retrace now,
    headroom for the generations after). Padding is bit-exact
    (:func:`repro.core.engine.pad_device_plan`). Plans whose signature
    (t/bits/n/k/groups) differs from the reference are left alone — that
    swap is architecturally different and rejected downstream anyway.
    """
    if ref_params is None:
        return _walk_dplans(
            params, None,
            lambda d, r: pad_device_plan(
                d, _round_pad(int(d.direct_idx.shape[-1]))))

    def align(d: DevicePlan, r: DevicePlan | None) -> DevicePlan:
        if r is None or (d.t, d.bits, d.n, d.k, d.groups) != (
                r.t, r.bits, r.n, r.k, r.groups):
            return d
        need = int(d.direct_idx.shape[-1])
        have = int(r.direct_idx.shape[-1])
        return pad_device_plan(d, have if need <= have
                               else _round_pad(need))

    return _walk_dplans(params, ref_params, align)


def build_generation(model, params, *, ref: Any = None, gen: int = 0,
                     tag: Any = None, cache=None, mesh=None,
                     specs=None) -> Generation:
    """Plan + compile + attach + align ONE weight generation, off-path.

    ``params`` are raw (un-attached) weights; ``ref`` is the currently
    *serving* generation's params (attached), used only for pad
    alignment — pass ``None`` for a cold start. Plans build through
    ``cache`` (default: the process cache, which is also what the
    qlinear host-callback backends consult — warming it here keeps even
    the non-device-resident ``engine`` backend's first post-swap decode
    off the plan-build path). Non-PTQ / non-planned configs pass the
    params through untouched (a generation is then just a tagged params
    handle). Raises whatever the plan build raises — the caller
    (:class:`ReplanWorker`) turns that into "keep serving the previous
    generation".
    """
    t0 = time.perf_counter()
    cache = plancache.default_cache() if cache is None else cache
    fp = fingerprint_params(params)
    q = getattr(model.cfg, "quant", None)
    built = 0
    attached = params
    if q is not None and q.mode == "ptq":
        b = get_backend(q)
        if b.needs_plan:
            built = plancache.precompile(params, q, cache)["built"]
        if b.needs_plan and b.device_resident:
            attached = plancache.attach_device_plans(params, q, cache)
            attached = align_device_plans(attached, ref)
            if mesh is not None:
                sp = specs if specs is not None else b.plan_specs(mesh)
                attached = _walk_dplans(
                    attached, None,
                    lambda d, r: shard_device_plan(d, mesh, sp))
    return Generation(gen=gen, params=attached, fingerprint=fp, tag=tag,
                      build_s=time.perf_counter() - t0, plans_built=built)


class ReplanSuperseded(RuntimeError):
    """A queued (not yet started) replan was replaced by newer weights
    before its build began; its ticket resolves with this error."""


class ReplanTicket:
    """Handle on one submitted replan: wait on it, read the result."""

    def __init__(self, fingerprint: str):
        self.fingerprint = fingerprint
        self.generation: Generation | None = None
        self.error: BaseException | None = None
        self._event = threading.Event()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the build finished (ok or failed); False on
        timeout."""
        return self._event.wait(timeout)

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def _resolve(self, generation=None, error=None) -> None:
        self.generation, self.error = generation, error
        self._event.set()

    def __repr__(self) -> str:
        state = ("pending" if not self.done else
                 "failed" if self.error is not None else "ready")
        return f"ReplanTicket({self.fingerprint[:8]}, {state})"


class ReplanWorker:
    """Background thread that rebuilds plan generations off the hot path.

    ``submit(params)`` fingerprints the weights and returns a
    :class:`ReplanTicket` immediately; the worker thread runs
    :func:`build_generation` and calls ``on_ready(generation)`` — wire
    that to ``ServeEngine.swap_params`` (which only *stages*; the engine
    applies at its next step boundary, so calling it from this thread is
    safe). On a build failure ``on_error(exc)`` fires and nothing
    reaches the engine: the previous generation keeps serving (the
    rollback guarantee).

    Coalescing mirrors the plan cache's ``_Pending`` discipline one
    level up: a submit whose fingerprint matches the build in flight,
    the queued build, or the last completed build returns that ticket
    instead of re-building. The queue is depth-1, newest wins — a
    superseded (never-started) ticket resolves with
    :class:`ReplanSuperseded`; re-planning for weights that are already
    stale would only delay the freshest ones.

    Alignment reference: the worker aligns each build against the params
    of the last generation it built (or the ``reference=`` it was seeded
    with — pass the engine's gen-0 serving params), which is exactly the
    aval chain the engine's decode jit has seen.
    """

    def __init__(self, model, *, cache=None, mesh=None, specs=None,
                 reference: Any = None,
                 on_ready: Callable[[Generation], Any] | None = None,
                 on_error: Callable[[BaseException], Any] | None = None):
        self.model = model
        self.cache = cache
        self.mesh = mesh
        self.specs = specs
        self.on_ready = on_ready
        self.on_error = on_error
        self._ref = reference
        self._lock = threading.Lock()
        self._wake = threading.Event()
        self._stop = False
        self._next: tuple[Any, Any, ReplanTicket] | None = None
        self._inflight: ReplanTicket | None = None
        self._last: ReplanTicket | None = None
        self._gen = 0
        self._thread: threading.Thread | None = None
        self.counters = {"submitted": 0, "coalesced": 0, "superseded": 0,
                         "built": 0, "failed": 0}

    # -- lifecycle ---------------------------------------------------------
    def _ensure_thread(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(target=self._loop,
                                            name="replan-worker",
                                            daemon=True)
            self._thread.start()

    def stop(self, timeout: float = 10.0) -> None:
        """Stop after the in-flight build (if any) finishes."""
        with self._lock:
            self._stop = True
            nxt, self._next = self._next, None
        if nxt is not None:
            self.counters["superseded"] += 1
            nxt[2]._resolve(error=ReplanSuperseded("worker stopped"))
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout)

    def __enter__(self) -> "ReplanWorker":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission --------------------------------------------------------
    def submit(self, params, *, tag: Any = None) -> ReplanTicket:
        """Schedule a rebuild for these weights; returns immediately."""
        fp = fingerprint_params(params)
        with self._lock:
            if self._stop:
                raise RuntimeError("ReplanWorker is stopped")
            self.counters["submitted"] += 1
            for t in (self._inflight, self._last):
                if (t is not None and t.fingerprint == fp
                        and t.error is None):
                    self.counters["coalesced"] += 1
                    return t
            if self._next is not None:
                if self._next[2].fingerprint == fp:
                    self.counters["coalesced"] += 1
                    return self._next[2]
                old = self._next[2]
                self.counters["superseded"] += 1
                old._resolve(error=ReplanSuperseded(
                    f"{old.fingerprint[:8]} superseded by {fp[:8]}"))
            ticket = ReplanTicket(fp)
            self._next = (params, tag, ticket)
        self._ensure_thread()
        self._wake.set()
        return ticket

    # -- the worker thread -------------------------------------------------
    def _loop(self) -> None:
        while True:
            self._wake.wait()
            with self._lock:
                if self._stop and self._next is None:
                    return
                self._wake.clear()
                job, self._next = self._next, None
                if job is None:
                    continue
                params, tag, ticket = job
                self._inflight = ticket
                self._gen += 1
                gen_id = self._gen
            try:
                gen = build_generation(
                    self.model, params, ref=self._ref, gen=gen_id,
                    tag=tag, cache=self.cache, mesh=self.mesh,
                    specs=self.specs)
            except BaseException as e:  # noqa: BLE001 — rollback path
                with self._lock:
                    self._inflight = None
                self.counters["failed"] += 1
                ticket._resolve(error=e)
                if self.on_error is not None:
                    self.on_error(e)
            else:
                with self._lock:
                    self._inflight = None
                    self._last = ticket
                    self._ref = gen.params
                self.counters["built"] += 1
                ticket._resolve(generation=gen)
                if self.on_ready is not None:
                    self.on_ready(gen)

    def stats(self) -> dict:
        with self._lock:
            return {**self.counters,
                    "inflight": self._inflight is not None,
                    "queued": self._next is not None}


class WeightWatcher:
    """Poll a checkpoint directory for new weights, feed them to a
    :class:`ReplanWorker`.

    ``ckpt_dir`` uses the ``repro.distributed.checkpoint`` layout
    (``step_N/`` + ``latest`` marker — the marker is written last, so a
    half-written checkpoint is never picked up). ``template`` is a
    params tree with the expected structure/shapes (e.g. the raw params
    the engine was started from). The serve loop calls :meth:`poll`
    between host steps; it is cheap (one small file read) until a new
    step appears, at which point the restore + ``worker.submit`` happen
    synchronously and the plan build itself runs on the worker thread.
    """

    def __init__(self, ckpt_dir, template, worker: ReplanWorker):
        self.ckpt_dir = ckpt_dir
        self.template = template
        self.worker = worker
        self.seen_step: int | None = None

    def poll(self) -> ReplanTicket | None:
        """Check for a new checkpoint; submit it if found."""
        from repro.distributed import checkpoint

        step = checkpoint.latest_step(self.ckpt_dir)
        if step is None or step == self.seen_step:
            return None
        params = checkpoint.restore(self.ckpt_dir, step, self.template)
        self.seen_step = step
        return self.worker.submit(params, tag=step)
