"""Live-weight serving fleet layer: async re-plan, hot-swap, bundles.

Transitive Array's execution plans are functions of the weight
*bit-patterns*, so weight updates invalidate every plan. This package
keeps serve cells alive through weight churn:

  * :mod:`repro.fleet.replan` — :class:`ReplanWorker` builds new plan
    generations on a background thread (:func:`build_generation`),
    pad-aligned so the serve engine's decode jit is not retraced;
    :class:`WeightWatcher` feeds it from a checkpoint directory.
  * :mod:`repro.fleet.bundles` — plan once on a planner role, write a
    fingerprinted manifest, attach on N server cells with zero plan
    builds (:func:`write_bundles` / :func:`load_bundles`).

The hot-swap protocol itself lives on ``ServeEngine.swap_params``
(serve/engine.py); docs/FLEET.md documents the whole lifecycle.
"""
from repro.fleet.bundles import (MANIFEST, load_bundles, read_manifest,
                                 write_bundles)
from repro.fleet.replan import (Generation, ReplanSuperseded, ReplanTicket,
                                ReplanWorker, WeightWatcher,
                                align_device_plans, build_generation,
                                fingerprint_params)

__all__ = ["Generation", "MANIFEST", "ReplanSuperseded", "ReplanTicket",
           "ReplanWorker", "WeightWatcher", "align_device_plans",
           "build_generation", "fingerprint_params", "load_bundles",
           "read_manifest", "write_bundles"]
