"""Plan-bundle distribution: plan once, serve everywhere.

The offline half of a Transitive Array deployment is expensive (the
scoreboard/DAG build per weight) and — today — redundantly paid on every
serve cell. This module turns the backend-tagged
``ExecutionPlan.save(device=, backend=)`` / ``load_bundle`` persistence
into a *fleet artifact*:

  * a **planner** role walks the params once, builds + compiles every
    PTQ layer's plans, and writes one ``.npz`` bundle per weight slice
    plus a ``manifest.json`` carrying the global weight fingerprint, the
    ``EngineConfig`` knobs, the backend registry name and per-file
    SHA-256 hashes (:func:`write_bundles`);
  * N **server** cells :func:`load_bundles` + attach instead of
    planning: the manifest fingerprint is checked against the cell's own
    weights (refusal on mismatch — a stale bundle would silently serve
    the *old* weights' GEMM), every file hash is verified, and each
    slice re-validates its own stored fingerprint through
    ``ExecutionPlan.load_bundle(qw=...)``. The result is params with
    ``"dplan"``s embedded, exactly like
    ``Model.attach_device_plans`` — but with **zero plan builds** on the
    serve cell.

``force=True`` is the explicit escape hatch past the fingerprint/config
refusals (file-hash corruption still refuses: that is damage, not
drift). File layout: flat directory, ``manifest.json`` written last.
"""
from __future__ import annotations

import hashlib
import json
import os
import time
from typing import Any

import numpy as np

from repro.core.backend import (EngineConfig, get_backend,
                                shard_device_plan)
from repro.core.engine import (BundleMismatchError, DevicePlan,
                               ExecutionPlan, compile_plan)
from repro.core.plancache import (_canonical, _cfg_backend, _is_ptq_layer,
                                  _layer_groups, _plan_knobs,
                                  default_cache, weight_fingerprint)
from repro.fleet.replan import fingerprint_params

__all__ = ["MANIFEST", "load_bundles", "read_manifest", "write_bundles"]

MANIFEST = "manifest.json"
_FORMAT = 1


def _sha256(path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _iter_layer_paths(tree: Any, path: tuple = ()):
    """Yield ``("a/b/c", layer_dict)`` for every PTQ layer, in the same
    deterministic walk order as the plancache attach walk — write and
    load key layers by this path, so both sides must agree."""
    if isinstance(tree, dict):
        if _is_ptq_layer(tree):
            yield "/".join(map(str, path)), tree
            return
        for k, v in tree.items():
            yield from _iter_layer_paths(v, path + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _iter_layer_paths(v, path + (i,))


def write_bundles(params: Any, cfg: Any, out_dir, *, backend=None,
                  cache=None) -> dict:
    """Planner role: plan + compile every PTQ layer, persist to
    ``out_dir``, return the manifest (also written as manifest.json).

    ``cfg`` names the serving quantization (a ``QuantConfig`` or
    ``EngineConfig``); ``backend=`` overrides which registry backend's
    ``compile`` hook lowers the device plans (default: the one ``cfg``
    names, else ``engine_jit``). Stacked (scan-over-blocks) layers write
    one file per slice, all padded to the layer's shared direct bound so
    the loader can restack them without re-padding.
    """
    cache = default_cache() if cache is None else cache
    b = _cfg_backend(cfg, backend)
    if b is None:
        b = get_backend("engine_jit")
    if not (b.needs_plan and b.device_resident):
        raise ValueError(
            f"backend '{b.name}' does not execute from device plans; "
            f"plan bundles distribute the planned device backends")
    w_bits, t = _plan_knobs(cfg)
    os.makedirs(out_dir, exist_ok=True)
    t0 = time.perf_counter()
    layers: dict[str, dict] = {}
    n_files = 0
    for lpath, layer in _iter_layer_paths(params):
        qw = np.asarray(layer["qw"])
        sg = np.asarray(layer["sg"])
        ecfg = EngineConfig(w_bits=w_bits, t=t,
                            groups=_layer_groups(sg))
        lead = qw.shape[:-2]
        idxs = list(np.ndindex(*lead)) if lead else [()]
        plans = [cache.get_or_build(qw[i] if i else qw, ecfg,
                                    backend=b.name) for i in idxs]
        # one shared direct bound per layer: the loader restacks the
        # slices, and stacking needs identical leaf shapes
        d = max(max(p.direct_tile.size for p in plans), 1)
        entries = []
        safe = lpath.replace("/", "__")
        for i, plan in zip(idxs, plans):
            qslice = qw[i] if i else qw
            fp = weight_fingerprint(_canonical(qslice))
            fname = (f"{safe}__{'_'.join(map(str, i))}.npz" if i
                     else f"{safe}.npz")
            fpath = os.path.join(out_dir, fname)
            plan.save(fpath, device=compile_plan(plan, direct_pad=d),
                      backend=b.name, fingerprint=fp)
            entries.append({"file": fname, "index": list(i),
                            "fingerprint": fp, "sha256": _sha256(fpath)})
            n_files += 1
        layers[lpath] = {"lead": list(lead), "groups": ecfg.groups,
                         "direct_pad": d, "files": entries}
    manifest = {"format": _FORMAT, "backend": b.name,
                "engine_config": {"w_bits": w_bits, "t": t},
                "weights_fingerprint": fingerprint_params(params),
                "n_layers": len(layers), "n_files": n_files,
                "layers": layers,
                "plan_wall_s": time.perf_counter() - t0}
    with open(os.path.join(out_dir, MANIFEST), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    return manifest


def read_manifest(bundle_dir) -> dict:
    path = os.path.join(bundle_dir, MANIFEST)
    if not os.path.exists(path):
        raise FileNotFoundError(
            f"no {MANIFEST} in {bundle_dir} — not a plan-bundle "
            f"directory (write one with the planner role)")
    with open(path) as f:
        return json.load(f)


def load_bundles(params: Any, cfg: Any, bundle_dir, *,
                 force: bool = False, mesh=None, specs=None,
                 cache=None) -> Any:
    """Server role: attach persisted DevicePlans instead of planning.

    Returns a copy of ``params`` with ``"dplan"`` embedded per PTQ
    layer, like ``attach_device_plans`` — but every plan comes from
    ``bundle_dir``, validated three ways before it is trusted:

      1. manifest-level: global weight fingerprint vs these params,
         backend + EngineConfig vs the serving ``cfg``, layer-path set
         vs the params walk (all :class:`BundleMismatchError`, skipped
         by ``force=True`` except missing layers);
      2. file-level: SHA-256 of every bundle file (corruption always
         refuses — ``force`` does not bypass damaged bytes);
      3. slice-level: ``ExecutionPlan.load_bundle(qw=slice, cfg=...)``
         re-checks the stored per-slice fingerprint (the satellite
         validation this module rides on).

    ``cache`` is untouched on the happy path — the point is that the
    serve cell builds zero plans.
    """
    manifest = read_manifest(bundle_dir)
    b = _cfg_backend(cfg, None)
    if b is None:
        b = get_backend("engine_jit")
    # trust boundary: structural coherence before any semantic checks —
    # a malformed manifest never reaches the mismatch logic below
    from repro.analysis.planlint import gate_bundle_file, gate_manifest
    gate_manifest(manifest, where="bundle-load", bundle_dir=bundle_dir,
                  backend=b.name)
    w_bits, t = _plan_knobs(cfg)
    mcfg = manifest.get("engine_config", {})
    if not force:
        if manifest.get("format") != _FORMAT:
            raise BundleMismatchError(
                f"{bundle_dir}: manifest format "
                f"{manifest.get('format')} != {_FORMAT}")
        if manifest.get("backend") != b.name:
            raise BundleMismatchError(
                f"{bundle_dir}: bundles were compiled for backend "
                f"'{manifest.get('backend')}', this cell serves "
                f"'{b.name}' (plan lowerings are backend-tagged); pass "
                f"force=True to attach anyway")
        if (mcfg.get("w_bits"), mcfg.get("t")) != (w_bits, t):
            raise BundleMismatchError(
                f"{bundle_dir}: bundle engine_config {mcfg} does not "
                f"match serving (w_bits={w_bits}, t={t})")
        fp = fingerprint_params(params)
        want = manifest.get("weights_fingerprint")
        if fp != want:
            raise BundleMismatchError(
                f"{bundle_dir}: bundles were planned from weights "
                f"{want}, this cell holds {fp} — a stale bundle would "
                f"serve the old weights' GEMM; re-plan (planner role) "
                f"or pass force=True")
    if mesh is not None and specs is None:
        specs = b.plan_specs(mesh)
    layers = dict(manifest["layers"])
    ecfg_of = {lp: EngineConfig(w_bits=w_bits, t=t,
                                groups=int(m["groups"]))
               for lp, m in layers.items()}

    import jax
    import jax.numpy as jnp

    def attach(lpath: str, layer: dict) -> dict:
        meta = layers.pop(lpath, None)
        if meta is None:
            raise BundleMismatchError(
                f"{bundle_dir}: no bundle for layer '{lpath}' — the "
                f"manifest covers a different model")
        qw = np.asarray(layer["qw"])
        lead = qw.shape[:-2]
        if list(lead) != list(meta["lead"]):
            raise BundleMismatchError(
                f"{bundle_dir}: layer '{lpath}' lead axes {lead} != "
                f"manifest {meta['lead']}")
        devices = []
        for e in meta["files"]:
            fpath = os.path.join(bundle_dir, e["file"])
            # structural verification FIRST: a truncated/corrupted npz
            # is refused by planlint before the hash is even computed
            gate_bundle_file(fpath, where="bundle-load",
                             backend=b.name)
            if _sha256(fpath) != e["sha256"]:
                raise BundleMismatchError(
                    f"{fpath}: file hash mismatch — bundle corrupted "
                    f"or tampered (force= does not bypass this)")
            i = tuple(e["index"])
            bundle = ExecutionPlan.load_bundle(
                fpath, qw=(qw[i] if i else qw),
                cfg=ecfg_of[lpath], force=force)
            dev = bundle.device
            if dev is None:  # plan-only file: lower locally, once
                dev = b.compile(bundle.plan)
            devices.append(dev)
        if lead:
            dplan = jax.tree.map(lambda *ls: jnp.stack(ls), *devices)
            dplan = jax.tree.map(
                lambda a: a.reshape(lead + a.shape[1:]), dplan)
        else:
            dplan = devices[0]
        if mesh is not None and isinstance(dplan, DevicePlan):
            dplan = shard_device_plan(dplan, mesh, specs)
        return {**layer, "dplan": dplan}

    def walk(tree: Any, path: tuple = ()):
        if isinstance(tree, dict):
            if _is_ptq_layer(tree):
                return attach("/".join(map(str, path)), tree)
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path + (i,)) for i, v in enumerate(tree)]
        if isinstance(tree, tuple):
            return tuple(walk(v, path + (i,))
                         for i, v in enumerate(tree))
        return tree

    out = walk(params)
    if layers and not force:
        raise BundleMismatchError(
            f"{bundle_dir}: manifest carries bundles for layers not in "
            f"these params: {sorted(layers)}")
    return out
