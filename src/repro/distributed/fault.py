"""Fault-tolerance policies: restart-from-checkpoint, straggler detection,
elastic re-meshing.

The runtime contract (DESIGN.md §4):
  * every state mutation passes through the CheckpointManager at a step
    cadence; the data pipeline is keyed by step → restarts are exact;
  * ``run_with_restarts`` wraps the training loop: any exception (device
    loss, preemption signal) triggers restore-from-latest and resume, up to
    ``max_restarts``; the mesh is rebuilt from the *currently healthy*
    device set, and restore reshards (elastic scale-up/down);
  * ``StragglerMonitor`` tracks per-step wall times; a step slower than
    ``threshold`` x the rolling median flags a straggler — on TPU pods the
    remediation is re-sharding around the slow host (here: logged + counted,
    and surfaced to the caller so orchestration can act).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

log = logging.getLogger("repro.fault")

__all__ = ["StragglerMonitor", "run_with_restarts", "Preemption"]


class Preemption(Exception):
    """Raised (e.g. by a signal handler) to simulate/flag preemption."""


@dataclasses.dataclass
class StragglerMonitor:
    threshold: float = 3.0
    window: int = 32

    def __post_init__(self):
        self.times: list[float] = []
        self.stragglers = 0
        self._t0 = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> bool:
        dt = time.monotonic() - self._t0
        hist = self.times[-self.window:]
        is_straggler = False
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if dt > self.threshold * med:
                self.stragglers += 1
                is_straggler = True
                log.warning("straggler step: %.3fs vs median %.3fs", dt, med)
        self.times.append(dt)
        return is_straggler


def run_with_restarts(make_loop: Callable[[int], int], max_restarts: int = 3):
    """``make_loop(start_step) -> final_step`` runs until done or raises.

    On exception, re-invoke (the loop re-discovers the latest checkpoint and
    the healthy device set). Returns (final_step, n_restarts).
    """
    restarts = 0
    while True:
        try:
            final = make_loop(restarts)
            return final, restarts
        except Preemption as e:           # noqa: PERF203
            restarts += 1
            log.warning("restart %d after preemption: %s", restarts, e)
            if restarts > max_restarts:
                raise
        except Exception as e:
            restarts += 1
            log.error("restart %d after failure: %s", restarts, e)
            if restarts > max_restarts:
                raise
