"""Mesh-agnostic checkpointing with atomic commits and async save.

Format: <dir>/step_<N>/
  manifest.json    — tree structure, shapes, dtypes, step, wall time
  <leaf-id>.npy    — full (unsharded) array per leaf

Checkpoints store *logical* arrays, so restore works under ANY mesh — the
elastic-scaling path (DESIGN.md §4): a job restarted with a different chip
count rebuilds its mesh and reshards on load via
``jax.make_array_from_callback`` (each device reads only its slice).

Atomicity: write into ``.tmp-step_<N>``, fsync files, then rename. A
``latest`` marker file is updated last. Partially-written checkpoints are
never visible and are garbage-collected on the next save.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

__all__ = ["save", "restore", "latest_step", "CheckpointManager"]

_SEP = "::"
_NUMPY_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
                 "int8", "uint64", "uint32", "uint16", "uint8", "bool"}
_BITS_DTYPE = {1: np.uint8, 2: np.uint16, 4: np.uint32, 8: np.uint64}


def _decode(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _NUMPY_NATIVE or str(arr.dtype) == dtype_str:
        return arr
    import ml_dtypes
    return np.asarray(arr).view(np.dtype(getattr(ml_dtypes, dtype_str)))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[key] = leaf
    return out, treedef


def save(ckpt_dir: str, step: int, tree, blocking: bool = True) -> None:
    """Device-get the tree and write an atomic checkpoint."""
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = os.path.join(ckpt_dir, f".tmp-step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "time": time.time(), "leaves": {}}
        for i, (k, a) in enumerate(sorted(host.items())):
            fname = f"leaf_{i:05d}.npy"
            dtype = str(a.dtype)
            if dtype not in _NUMPY_NATIVE:
                # ml_dtypes (bfloat16, fp8, ...) don't survive np.save —
                # store the raw bits and reinterpret on load.
                a = a.view(_BITS_DTYPE[a.dtype.itemsize])
            np.save(os.path.join(tmp, fname), a)
            manifest["leaves"][k] = {
                "file": fname, "shape": list(a.shape), "dtype": dtype}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        with open(os.path.join(ckpt_dir, "latest.tmp"), "w") as f:
            f.write(str(step))
            f.flush()
            os.fsync(f.fileno())
        os.replace(os.path.join(ckpt_dir, "latest.tmp"),
                   os.path.join(ckpt_dir, "latest"))

    if blocking:
        _write()
    else:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t


def latest_step(ckpt_dir: str) -> int | None:
    marker = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(marker):
        return None
    step = int(open(marker).read().strip())
    if os.path.isdir(os.path.join(ckpt_dir, f"step_{step:08d}")):
        return step
    return None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Load a checkpoint into the structure of ``target_tree``.

    ``shardings``: optional matching pytree of jax.sharding.Sharding — when
    given, each array is materialised shard-by-shard under the *current*
    mesh (elastic reshard). Otherwise arrays land as host-local.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(target_tree)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    out = {}
    for key, ref in flat_t.items():
        meta = manifest["leaves"][key]
        raw = np.load(os.path.join(path, meta["file"]), mmap_mode="r")
        arr = _decode(raw, meta["dtype"])
        assert tuple(arr.shape) == tuple(ref.shape), (key, arr.shape, ref.shape)
        if key in flat_s and flat_s[key] is not None:
            sh = flat_s[key]
            out[key] = jax.make_array_from_callback(
                arr.shape, sh, lambda idx, a=arr: np.asarray(a[idx]))
        else:
            out[key] = jax.numpy.asarray(np.asarray(arr)).astype(ref.dtype)
    leaves = [out[k] for k in sorted(flat_t)]
    ordered = [out[k] for k in flat_t]
    del leaves
    return jax.tree_util.tree_unflatten(treedef, ordered)


class CheckpointManager:
    """Keep-latest-K manager with async save and restart discovery."""

    def __init__(self, ckpt_dir: str, keep: int = 3, async_save: bool = True):
        self.dir = ckpt_dir
        self.keep = keep
        self.async_save = async_save
        self._pending: threading.Thread | None = None

    def save(self, step: int, tree):
        self.wait()
        self._pending = save(self.dir, step, tree,
                             blocking=not self.async_save)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        if not os.path.isdir(self.dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target_tree, shardings=None):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, 0
        return restore(self.dir, step, target_tree, shardings), step
