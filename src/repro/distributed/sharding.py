"""Logical-axis sharding rules → PartitionSpecs / constraints.

Logical axes:
  batch   → ("pod", "data")   data parallelism (pod = DCN-level DP)
  heads   → "model"           tensor parallelism over attention heads
  kv_heads→ "model"           (replicated when GQA kv count not divisible)
  ffn     → "model"           tensor parallelism over FFN inner dim
  vocab   → "model"           sharded embedding / logits
  experts → "model"           expert parallelism
  kv_seq  → "model"           sequence parallelism for decode KV caches
  seq     → "model" iff cfg.seq_shard (Megatron-SP activations)
  fsdp    → "data"            ZeRO-3-ish parameter sharding on the DP axis

``shard(x, *logical_axes)`` applies a sharding constraint only when a mesh
with the needed axis names is ambient (jit under ``with mesh:``) and the
dimension is divisible — so the same model code runs on 1 CPU device in
tests and on the 512-chip production mesh in the dry-run. Dropping an axis
for non-divisibility is legal but no longer silent: the first time a given
(logical axis, mesh extent, dim) combination replicates instead of
sharding, :func:`spec` emits a ``ShardingDropWarning`` — a serve cell that
meant to split its batch 4 ways but quietly ran 4 replicated copies is
exactly the failure mode the warning exists for.
"""
from __future__ import annotations

import warnings

import jax
from jax.sharding import PartitionSpec as P

from repro import jax_compat

__all__ = ["RULES", "ShardingDropWarning", "spec", "shard",
           "mesh_axis_size"]


class ShardingDropWarning(UserWarning):
    """A sharding rule's mesh axes were dropped (replicated) because the
    mesh extent does not divide the dimension."""


# (logical axis, mesh axes, dim, extent) combinations already warned about —
# spec() runs on every layer of every step, the warning must fire once
_WARNED_DROPS: set[tuple] = set()


def _warn_drop(name: str, mesh_axes: tuple[str, ...], dim: int,
               size: int) -> None:
    key = (name, mesh_axes, dim, size)
    if key in _WARNED_DROPS:
        return
    _WARNED_DROPS.add(key)
    axes = "+".join(mesh_axes)
    product = " (product of present axes)" if len(mesh_axes) > 1 else ""
    warnings.warn(
        f"sharding rule '{name}' -> mesh axes {mesh_axes} dropped: "
        f"dim {dim} is not divisible by the mesh extent {size} of "
        f"{axes}{product}; the dimension is REPLICATED on every device. "
        f"Pad the dimension or resize the mesh to actually shard it.",
        ShardingDropWarning, stacklevel=3)

RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "kv_seq": ("model",),
    "seq_sp": ("model",),
    "fsdp": ("data",),
    "none": (),
}


def _ambient_mesh():
    return jax_compat.get_abstract_mesh()


def mesh_axis_size(name: str) -> int:
    m = _ambient_mesh()
    if m is None or name not in m.axis_names:
        return 1
    return m.shape[name]


def spec(*logical_axes: str | None, shape: tuple[int, ...] | None = None,
         mesh=None) -> P:
    """PartitionSpec from logical axis names (None → replicated dim).

    When ``shape`` is given, axes whose mesh extent does not divide the dim
    are dropped (replicated) — e.g. 8 GQA kv heads on a 16-way model axis.
    For multi-axis rules (``batch`` → ``("pod", "data")``) the *product* of
    the present axes must divide. A drop emits a ``ShardingDropWarning``
    once per (rule, extent, dim) — replication is a legal fallback, not a
    silent one. ``mesh`` defaults to the ambient mesh.
    """
    m = _ambient_mesh() if mesh is None else mesh
    parts = []
    for i, name in enumerate(logical_axes):
        if name is None or name == "none":
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in RULES[name]
                          if m is not None and a in m.axis_names)
        if not mesh_axes:
            parts.append(None)
            continue
        size = 1
        for a in mesh_axes:
            size *= dict(m.shape)[a]
        if shape is not None and shape[i] % size:
            if size > 1:
                _warn_drop(name, mesh_axes, shape[i], size)
            parts.append(None)
            continue
        parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*parts)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint under the ambient mesh; no-op without one."""
    if _ambient_mesh() is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    s = spec(*logical_axes, shape=x.shape)
    if all(p is None for p in s):
        return x
    return jax.lax.with_sharding_constraint(x, s)


# --------------------------------------------------------------------------
# Parameter sharding rules (Megatron TP + ZeRO-3 FSDP on the data axis)
# --------------------------------------------------------------------------

# row-parallel linears: contraction (input) dim carries the TP shard
_ROW_PARALLEL = {"wo", "down", "w_out"}
# leaves sharded over experts on "model" (+ FSDP on a wide inner dim)
_EXPERT = {"w_gate", "w_up", "w_down"}


def _leaf_logical(path_keys: list[str], shape) -> tuple[str | None, ...]:
    name = None
    for k in reversed(path_keys):
        if k not in ("w", "qw", "sg"):
            name = k
            break
    ndim = len(shape)
    lead = (None,) * (ndim - 2)                    # scan-stacked axes

    if name in ("embed", "unembed"):
        return ("vocab", "fsdp")
    if name in _EXPERT and ndim >= 3:
        # (R?, E, d_in, d_out): experts on model, last dim ZeRO-3
        logical = [None] * ndim
        logical[ndim - 3] = "experts"
        logical[ndim - 1] = "fsdp"
        return tuple(logical)
    if ndim < 2:
        return (None,) * ndim                      # norms, scalars, lam
    if name == "router":
        return lead + (None, None)
    if name in _ROW_PARALLEL:
        return lead + ("fsdp", "heads")            # (out, in): in = model
    # column-parallel default: (out, in) with out on model, in on data
    return lead + ("heads", "fsdp")


def param_specs(params, fsdp: bool = True) -> object:
    """Pytree of PartitionSpecs for a params/opt-state tree.

    Layout convention: qlinear weights are (d_out, d_in) (possibly with
    leading stacked scan axes). Column-parallel weights shard d_out on
    "model"; row-parallel ({wo, down, w_out}) shard d_in on "model"; the
    other big dim takes ZeRO-3 ("data") where divisible. MoE expert stacks
    shard experts on "model" and their widest dim on "data"; scales/norms
    replicate.

    ``fsdp=False`` drops the ZeRO-3 ("data") axis — the serving layout:
    weights stay TP-resident instead of being all-gathered every step
    (EXPERIMENTS.md §Perf iteration 1).
    """
    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        logical = _leaf_logical(keys, leaf.shape)
        if not fsdp:
            logical = tuple(None if ax == "fsdp" else ax for ax in logical)
        return spec(*logical, shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)
