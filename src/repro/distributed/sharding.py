"""Logical-axis sharding rules → PartitionSpecs / constraints.

Logical axes:
  batch   → ("pod", "data")   data parallelism (pod = DCN-level DP)
  heads   → "model"           tensor parallelism over attention heads
  kv_heads→ "model"           (replicated when GQA kv count not divisible)
  ffn     → "model"           tensor parallelism over FFN inner dim
  vocab   → "model"           sharded embedding / logits
  experts → "model"           expert parallelism
  kv_seq  → "model"           sequence parallelism for decode KV caches
  seq     → "model" iff cfg.seq_shard (Megatron-SP activations)
  fsdp    → "data"            ZeRO-3-ish parameter sharding on the DP axis

``shard(x, *logical_axes)`` applies a sharding constraint only when a mesh
with the needed axis names is ambient (jit under ``with mesh:``) and the
dimension is divisible — so the same model code runs on 1 CPU device in
tests and on the 512-chip production mesh in the dry-run.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro import jax_compat

__all__ = ["RULES", "spec", "shard", "mesh_axis_size"]

RULES: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ffn": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "kv_seq": ("model",),
    "seq_sp": ("model",),
    "fsdp": ("data",),
    "none": (),
}


def _ambient_mesh():
    return jax_compat.get_abstract_mesh()


def mesh_axis_size(name: str) -> int:
    m = _ambient_mesh()
    if m is None or name not in m.axis_names:
        return 1
    return m.shape[name]


def spec(*logical_axes: str | None, shape: tuple[int, ...] | None = None) -> P:
    """PartitionSpec from logical axis names (None → replicated dim).

    When ``shape`` is given, axes whose mesh extent does not divide the dim
    are dropped (replicated) — e.g. 8 GQA kv heads on a 16-way model axis.
    """
    m = _ambient_mesh()
    parts = []
    for i, name in enumerate(logical_axes):
        if name is None or name == "none":
            parts.append(None)
            continue
        mesh_axes = tuple(a for a in RULES[name]
                          if m is not None and a in m.axis_names)
        if not mesh_axes:
            parts.append(None)
            continue
        if shape is not None:
            size = 1
            for a in mesh_axes:
                size *= m.shape[a]
            if shape[i] % size:
                parts.append(None)
                continue
        parts.append(mesh_axes if len(mesh_axes) > 1 else mesh_axes[0])
    return P(*parts)


def shard(x: jax.Array, *logical_axes: str | None) -> jax.Array:
    """with_sharding_constraint under the ambient mesh; no-op without one."""
    if _ambient_mesh() is None:
        return x
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    s = spec(*logical_axes, shape=x.shape)
    if all(p is None for p in s):
        return x
    return jax.lax.with_sharding_constraint(x, s)


# --------------------------------------------------------------------------
# Parameter sharding rules (Megatron TP + ZeRO-3 FSDP on the data axis)
# --------------------------------------------------------------------------

# row-parallel linears: contraction (input) dim carries the TP shard
_ROW_PARALLEL = {"wo", "down", "w_out"}
# leaves sharded over experts on "model" (+ FSDP on a wide inner dim)
_EXPERT = {"w_gate", "w_up", "w_down"}


def _leaf_logical(path_keys: list[str], shape) -> tuple[str | None, ...]:
    name = None
    for k in reversed(path_keys):
        if k not in ("w", "qw", "sg"):
            name = k
            break
    ndim = len(shape)
    lead = (None,) * (ndim - 2)                    # scan-stacked axes

    if name in ("embed", "unembed"):
        return ("vocab", "fsdp")
    if name in _EXPERT and ndim >= 3:
        # (R?, E, d_in, d_out): experts on model, last dim ZeRO-3
        logical = [None] * ndim
        logical[ndim - 3] = "experts"
        logical[ndim - 1] = "fsdp"
        return tuple(logical)
    if ndim < 2:
        return (None,) * ndim                      # norms, scalars, lam
    if name == "router":
        return lead + (None, None)
    if name in _ROW_PARALLEL:
        return lead + ("fsdp", "heads")            # (out, in): in = model
    # column-parallel default: (out, in) with out on model, in on data
    return lead + ("heads", "fsdp")


def param_specs(params, fsdp: bool = True) -> object:
    """Pytree of PartitionSpecs for a params/opt-state tree.

    Layout convention: qlinear weights are (d_out, d_in) (possibly with
    leading stacked scan axes). Column-parallel weights shard d_out on
    "model"; row-parallel ({wo, down, w_out}) shard d_in on "model"; the
    other big dim takes ZeRO-3 ("data") where divisible. MoE expert stacks
    shard experts on "model" and their widest dim on "data"; scales/norms
    replicate.

    ``fsdp=False`` drops the ZeRO-3 ("data") axis — the serving layout:
    weights stay TP-resident instead of being all-gathered every step
    (EXPERIMENTS.md §Perf iteration 1).
    """
    def one(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        logical = _leaf_logical(keys, leaf.shape)
        if not fsdp:
            logical = tuple(None if ax == "fsdp" else ax for ax in logical)
        return spec(*logical, shape=leaf.shape)

    return jax.tree_util.tree_map_with_path(one, params)
