"""Collective helpers: compressed cross-pod gradient reduction with error
feedback, and hierarchical psum (for use inside shard_map).

The int8 compressed all-reduce targets the slow DCN (pod) axis: gradients
are reduce-scattered intra-pod at full precision by XLA as usual; the
cross-pod exchange quantizes to int8 with a per-tensor scale and keeps the
quantization residual locally (error feedback), preserving convergence
(1-bit-Adam-style). DCN bytes drop ~4x for f32 / ~2x for bf16 grads.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["compressed_psum_tree", "hierarchical_psum"]


def _quantize(g: jnp.ndarray):
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_tree(grads, residuals, axis: str):
    """int8 all-reduce over ``axis`` with error feedback.

    grads/residuals: matching pytrees (residuals carried in train state).
    Returns (reduced_grads, new_residuals). Mean over the axis.
    """
    n = jax.lax.axis_size(axis)

    def one(g, r):
        g32 = g.astype(jnp.float32) + r.astype(jnp.float32)
        q, scale = _quantize(g32)
        deq = q.astype(jnp.float32) * scale
        new_r = g32 - deq
        summed = jax.lax.psum(q.astype(jnp.int32), axis)
        # scales differ per pod → psum the dequantized contribution scale;
        # cheap second scalar collective.
        scale_sum = jax.lax.pmean(scale, axis)
        out = summed.astype(jnp.float32) * scale_sum / n
        return out.astype(g.dtype), new_r.astype(r.dtype)

    out = jax.tree.map(one, grads, residuals)
    red = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    res = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda t: isinstance(t, tuple))
    return red, res


def hierarchical_psum(x, fast_axis: str, slow_axis: str):
    """reduce over ICI first, then DCN — the standard pod-hierarchy order."""
    return jax.lax.psum(jax.lax.psum(x, fast_axis), slow_axis)
