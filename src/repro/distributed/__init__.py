"""Distributed runtime: sharding rules, collectives, checkpoint, fault."""
