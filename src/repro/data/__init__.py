"""Data pipeline substrate."""
from repro.data.pipeline import SyntheticLM, batch_specs  # noqa: F401
