"""Deterministic synthetic LM data pipeline, sharded and restart-exact.

Batches are keyed by (seed, step) only — a restart at step N reproduces the
exact stream (fault-tolerance requirement, DESIGN.md §4). Tokens follow a
Zipf-like distribution with induced bigram structure so models actually
learn (loss decreases) in the end-to-end examples.

Layout: (grad_accum, micro_batch, seq) so the train step scans microbatches
without resharding; the micro_batch axis carries the ("pod","data") sharding.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec

__all__ = ["SyntheticLM", "batch_specs"]


@dataclasses.dataclass
class SyntheticLM:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.cfg.vocab
        # fixed random bigram successor table: token t -> t' (learnable)
        self.succ = rng.integers(0, v, size=v, dtype=np.int64)
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self.probs = p / p.sum()

    def batch(self, step: int, grad_accum: int = 1) -> dict:
        rng = np.random.default_rng((self.seed << 32) ^ step)
        b, s, v = self.global_batch, self.seq_len, self.cfg.vocab
        toks = np.empty((b, s + 1), dtype=np.int64)
        toks[:, 0] = rng.choice(v, size=b, p=self.probs)
        noise = rng.random((b, s))
        fresh = rng.choice(v, size=(b, s), p=self.probs)
        for t in range(s):
            follow = self.succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < 0.75, follow, fresh[:, t])
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.n_context_tokens or self.cfg.is_encdec:
            ctx = rng.standard_normal(
                (b, self.cfg.n_context_tokens, self.cfg.d_model)) * 0.02
            out["context"] = ctx.astype(np.float32)
        if grad_accum > 1:
            out = {k: a.reshape((grad_accum, b // grad_accum) + a.shape[1:])
                   for k, a in out.items()}
        else:
            out = {k: a[None] for k, a in out.items()}
        return {k: jnp.asarray(a) for k, a in out.items()}


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStructs for one training batch (dry-run stand-ins)."""
    a = cfg.grad_accum
    mb = shape.global_batch // a
    s = shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((a, mb, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((a, mb, s), jnp.int32),
    }
    if cfg.n_context_tokens or cfg.is_encdec:
        specs["context"] = jax.ShapeDtypeStruct(
            (a, mb, cfg.n_context_tokens, cfg.d_model), jnp.float32)
    return specs
