"""Version-compat layer for JAX APIs that moved between 0.4.x and newer.

The repo targets current JAX (explicit ``AxisType`` meshes, ambient
``jax.set_mesh`` / ``jax.sharding.get_abstract_mesh``, top-level
``jax.shard_map``), but the pinned container ships an older 0.4.x where
those live elsewhere or don't exist. Everything that needs one of these
APIs imports it from here so the rest of the codebase stays on the modern
spelling:

  * :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` only when the
    installed JAX knows ``jax.sharding.AxisType``.
  * :func:`get_abstract_mesh` — the ambient mesh, or ``None`` when no mesh
    context is active. Falls back to the thread-resources physical mesh
    that old JAX sets under ``with mesh:``.
  * :func:`set_mesh` — context manager entering a mesh; ``jax.set_mesh``
    when present, else the mesh object's own context manager.
  * :func:`shard_map` — ``jax.shard_map`` when present, else
    ``jax.experimental.shard_map.shard_map`` (mapping ``check_vma`` to the
    old ``check_rep`` flag).
  * :func:`pure_callback` — forwards ``vmap_method`` only where the
    installed JAX (>= 0.4.34) accepts it; older versions fall back to the
    legacy batching behaviour rather than raising ``TypeError``.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

__all__ = ["AxisType", "make_mesh", "get_abstract_mesh", "set_mesh",
           "shard_map", "pure_callback"]

try:  # jax >= 0.5.x
    from jax.sharding import AxisType
except ImportError:  # old jax: meshes have no axis types (all Auto)
    AxisType = None


def make_mesh(axis_shapes, axis_names):
    """``jax.make_mesh`` across versions (axis_types only where supported)."""
    if AxisType is None:
        return jax.make_mesh(axis_shapes, axis_names)
    return jax.make_mesh(axis_shapes, axis_names,
                         axis_types=(AxisType.Auto,) * len(axis_names))


def get_abstract_mesh():
    """The ambient mesh, or None when no mesh context is active."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        m = get()
        if m is None or not m.axis_names:
            return None
        return m
    from jax.interpreters import pxla
    m = pxla.thread_resources.env.physical_mesh
    if m.empty:
        return None
    return m


def set_mesh(mesh):
    """Context manager making ``mesh`` ambient for jit/sharding constraints."""
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    if hasattr(mesh, "__enter__"):          # old jax: Mesh is a context mgr
        return mesh
    return contextlib.nullcontext(mesh)


_PURE_CALLBACK_HAS_VMAP_METHOD = (
    "vmap_method" in inspect.signature(jax.pure_callback).parameters)


def pure_callback(callback, result_shape_dtypes, *args,
                  vmap_method: str | None = None, **kwargs):
    """``jax.pure_callback`` forwarding ``vmap_method`` where supported."""
    if vmap_method is not None and _PURE_CALLBACK_HAS_VMAP_METHOD:
        kwargs["vmap_method"] = vmap_method
    return jax.pure_callback(callback, result_shape_dtypes, *args, **kwargs)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """Top-level shard_map with the modern signature on any version."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as old_sm
    return old_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
