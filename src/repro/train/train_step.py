"""Train-step factory: microbatched grad accumulation, AdamW update,
optional int8+error-feedback cross-pod gradient compression.

State/step layout is donation-friendly: ``train_step(state, batch) ->
(state, metrics)`` with state donated, so parameters and optimizer moments
update in place on device.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import jax_compat
from repro.configs.base import ModelConfig
from repro.distributed.collectives import compressed_psum_tree
from repro.models.model import Model
from repro.optim import AdamW

TrainState = dict[str, Any]


def make_optimizer(cfg: ModelConfig) -> AdamW:
    mdt = jnp.bfloat16 if str(cfg.opt_state_dtype) in ("bfloat16", "bf16") \
        else jnp.float32
    return AdamW(moment_dtype=mdt, factored_v=cfg.factored_second_moment)


def init_state(model: Model, opt: AdamW, key) -> TrainState:
    params = model.init(key)
    return {"params": params, "opt": opt.init(params),
            "step": jnp.zeros((), jnp.int32)}


def state_shape(model: Model, opt: AdamW):
    return jax.eval_shape(lambda: init_state(model, opt,
                                             jax.random.PRNGKey(0)))


def _accum_grads(loss_fn, params, batch, n_micro: int,
                 accum_dtype=jnp.float32):
    """Scan microbatches, averaging loss and grads.

    ``accum_dtype=bfloat16`` halves the gradient-carry HBM (12 GB/dev for
    the 0.8T llama4 config) at a small accumulation-noise cost — paired
    with the bf16 optimizer moments it already uses."""
    if n_micro == 1:
        mb = jax.tree.map(lambda a: a[0], batch)
        return jax.value_and_grad(loss_fn)(params, mb)

    def micro(carry, mb):
        loss_sum, gsum = carry
        loss, g = jax.value_and_grad(loss_fn)(params, mb)
        gsum = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gsum, g)
        return (loss_sum + loss, gsum), None

    gzero = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
    (loss_sum, gsum), _ = jax.lax.scan(micro, (jnp.float32(0.0), gzero),
                                       batch)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, gsum)


def make_train_step(model: Model, opt: AdamW, lr_fn):
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are (grad_accum, micro_batch, ...). When
    cfg.compress_pod_grads is set and the ambient mesh has a "pod" axis,
    the cross-pod gradient mean runs as an int8 error-feedback collective
    inside shard_map (XLA still does full-precision ICI reductions inside
    each pod — only the slow DCN hop is compressed).
    """
    cfg = model.cfg

    accum_dtype = jnp.bfloat16 \
        if str(cfg.opt_state_dtype) in ("bfloat16", "bf16") else jnp.float32

    def loss_fn(p, mb):
        return model.loss(p, mb)

    def train_step(state: TrainState, batch):
        params = state["params"]
        loss, grads = _accum_grads(loss_fn, params, batch, cfg.grad_accum,
                                   accum_dtype)
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return new_state, {"loss": loss, "grad_norm": gnorm, "lr": lr}

    return train_step


def make_compressed_dp_train_step(model: Model, opt: AdamW, lr_fn, mesh,
                                  dp_axes=("pod", "data")):
    """Data-parallel train step fully inside shard_map, with the cross-pod
    gradient mean running as an int8 error-feedback collective
    (distributed-optimization trick, DESIGN.md §4).

    Params are replicated; the batch is sharded over ``dp_axes``. Intra-pod
    reduction ("data") stays full precision; only the slow DCN hop ("pod")
    is compressed. State carries the per-leaf quantization residuals.
    """
    cfg = model.cfg

    def local_step(state, batch):
        params = state["params"]
        loss, grads = _accum_grads(lambda p, mb: model.loss(p, mb),
                                   params, batch, cfg.grad_accum)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, "data"), grads)
        loss = jax.lax.pmean(jax.lax.pmean(loss, "data"), "pod")
        grads, new_res = compressed_psum_tree(grads, state["residual"],
                                              "pod")
        lr = lr_fn(state["step"])
        new_params, new_opt = opt.update(grads, state["opt"], params, lr)
        return ({"params": new_params, "opt": new_opt,
                 "step": state["step"] + 1, "residual": new_res},
                {"loss": loss})

    rep = P()
    bspec = P(None, dp_axes)      # (accum, micro_batch, ...) — batch axis

    def specs_like(tree, s):
        return jax.tree.map(lambda _: s, tree)

    def step(state, batch):
        state_specs = specs_like(state, rep)
        batch_specs = jax.tree.map(
            lambda a: P(None, dp_axes, *([None] * (a.ndim - 2))), batch)
        return jax_compat.shard_map(local_step, mesh=mesh,
                             in_specs=(state_specs, batch_specs),
                             out_specs=(state_specs, specs_like(
                                 {"loss": 0}, rep)),
                             check_vma=False)(state, batch)

    del bspec
    return step


def init_compressed_state(model: Model, opt: AdamW, key) -> TrainState:
    state = init_state(model, opt, key)
    state["residual"] = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
    return state
