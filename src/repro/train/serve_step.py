"""Serving-step factories: prefill + decode (the paper's inference setting —
quantized GEMMs through the Transitive Array path run here).

``make_decode_step`` is the unit the decode_* / long_* dry-run shapes lower:
one new token against a seq_len KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model import Model


def make_prefill(model: Model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_step(model: Model):
    def decode_step(params, caches, token, step):
        return model.decode_step(params, caches, token, step)
    return decode_step


def greedy_generate(model: Model, params, batch, max_len: int,
                    n_steps: int):
    """Prefill then greedy-decode n_steps tokens (example/driver path)."""
    logits, caches = jax.jit(make_prefill(model, max_len))(params, batch)
    step_fn = jax.jit(make_decode_step(model))
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    toks = [tok]
    pos = batch["tokens"].shape[1]
    for i in range(n_steps - 1):
        logits, caches = step_fn(params, caches, tok, jnp.int32(pos + i))
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
