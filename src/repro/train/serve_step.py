"""Serving-step factories: prefill + decode (the paper's inference setting —
quantized GEMMs through the Transitive Array path run here).

``make_decode_step`` is the unit the decode_* / long_* dry-run shapes lower:
one new token against a seq_len KV cache.

``greedy_generate`` is the host driver loop around them: one jitted
prefill, then one jitted decode step per generated token. The jitted
callables are memoised per model (``_jit_prefill`` / ``_jit_decode_step``)
so repeated ``greedy_generate`` calls — a serving loop — re-trace nothing,
and the decode step **donates its KV caches**: without donation every token
pays a full cache-buffer copy, which at production cache sizes is the
decode hot loop's single largest memory cost.

With ``mesh=`` the whole loop runs as a multi-device serve cell: the batch
is placed under ``P(("pod", "data"))`` on its leading axis (the logical
rules in ``distributed/sharding.py``), the mesh is ambient for prefill and
every decode step, and the model's internal sharding constraints keep
activations, caches, logits and the sampled tokens data-sharded between
steps. Params (and any attached DevicePlans) are placed by the caller —
replicated by default, which is the data-parallel decode topology.
"""
from __future__ import annotations

import contextlib
import weakref

import jax
import jax.numpy as jnp

from repro import jax_compat
from repro.distributed.sharding import spec
from repro.models.model import Model


def make_prefill(model: Model, max_len: int):
    def prefill(params, batch):
        return model.prefill(params, batch, max_len)
    return prefill


def make_decode_step(model: Model):
    def decode_step(params, caches, token, step):
        return model.decode_step(params, caches, token, step)
    return decode_step


# jitted step memo, weak-keyed by model: a fresh jax.jit wrapper per
# greedy_generate call would re-trace every time (jit caches on function
# identity, and the closure used to be rebuilt per call), while a strong
# cache would pin every Model + its compiled executables for the process
# lifetime
_STEP_JITS: "weakref.WeakKeyDictionary[Model, dict]" = \
    weakref.WeakKeyDictionary()


def _jit_prefill(model: Model, max_len: int, mesh=None):
    """One jitted prefill per (model, max_len, mesh).

    The ambient mesh is part of the key: tracing under ``set_mesh`` bakes
    the mesh into the step's sharding constraints, but the jit's own cache
    only keys on input avals/shardings — interleaved ``greedy_generate``
    calls with different ``mesh=`` values (or mesh then no-mesh) would
    otherwise silently reuse a step traced under the wrong mesh."""
    per = _STEP_JITS.setdefault(model, {})
    key = ("prefill", max_len, mesh)
    if key not in per:
        per[key] = jax.jit(make_prefill(model, max_len))
    return per[key]


def _jit_decode_step(model: Model, donate: bool, mesh=None):
    """One jitted decode step per (model, donate, mesh).

    Donating the caches lets XLA update them in place; the host loop only
    ever feeds the previous step's output back in, so the donated input
    buffer is dead by construction. ``mesh`` keys the memo for the same
    reason as :func:`_jit_prefill`."""
    per = _STEP_JITS.setdefault(model, {})
    key = ("decode", donate, mesh)
    if key not in per:
        per[key] = jax.jit(make_decode_step(model),
                           donate_argnums=(1,) if donate else ())
    return per[key]


def _place_batch(batch, mesh):
    """Shard the batch along the mesh's data axes: leading (batch) dim under
    the ``batch`` logical rule where divisible (``spec`` warns on a drop)."""
    from jax.sharding import NamedSharding

    def one(v):
        s = spec("batch", *([None] * (v.ndim - 1)), shape=v.shape,
                 mesh=mesh)
        return jax.device_put(v, NamedSharding(mesh, s))
    return jax.tree.map(one, batch)


def greedy_generate(model: Model, params, batch, max_len: int,
                    n_steps: int, *, mesh=None, donate: bool = True):
    """Prefill then greedy-decode; returns exactly ``n_steps`` tokens.

    Contract (explicit since PR 5): the result is ``(B, n_steps)`` int32.
    Token 0 is the argmax over the prefill logits at the last prompt
    position; tokens 1..n_steps-1 come from ``n_steps - 1`` decode steps.
    ``n_steps=0`` returns an empty ``(B, 0)`` array without running the
    model; negative ``n_steps`` raises. (The old loop ran
    ``range(n_steps - 1)`` decode steps *and* unconditionally emitted the
    prefill token, so ``n_steps=0`` still returned one token.)

    ``mesh=`` runs the loop as a multi-device serve cell: the batch is
    placed under the ``batch`` logical sharding rule and the mesh is
    ambient for prefill + every decode step — tokens come back
    bit-identical to the 1-device run (data parallelism never reorders a
    row's reductions). ``donate=False`` keeps the per-step cache copy, for
    callers that re-enter decode from a kept cache reference.
    """
    if n_steps < 0:
        raise ValueError(f"n_steps must be >= 0, got {n_steps}")
    b, prompt_len = batch["tokens"].shape
    if n_steps == 0:
        return jnp.zeros((b, 0), jnp.int32)
    ctx = jax_compat.set_mesh(mesh) if mesh is not None \
        else contextlib.nullcontext()
    with ctx:
        if mesh is not None:
            batch = _place_batch(batch, mesh)
        logits, caches = _jit_prefill(model, max_len, mesh)(params, batch)
        step_fn = _jit_decode_step(model, donate, mesh)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        toks = [tok]
        for i in range(n_steps - 1):
            logits, caches = step_fn(params, caches, tok,
                                     jnp.int32(prompt_len + i))
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            toks.append(tok)
        return jnp.concatenate(toks, axis=1)
