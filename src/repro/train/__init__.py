"""Training/serving step factories and the fault-tolerant loop."""
from repro.train.train_step import TrainState, make_train_step, make_optimizer  # noqa: F401
from repro.train.serve_step import make_prefill, make_decode_step  # noqa: F401
