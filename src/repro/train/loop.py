"""Fault-tolerant training loop: checkpoint cadence, exact-restart data,
straggler monitoring, metrics logging.

``train(cfg, shape, steps, ckpt_dir)`` is what examples/ and launch/train.py
drive; it is resumable — rerunning with the same ckpt_dir continues from the
latest checkpoint (the restart path run_with_restarts exercises).
"""
from __future__ import annotations

import json
import logging
import os
import time

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import SyntheticLM
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.fault import StragglerMonitor
from repro.models.model import Model
from repro.optim.schedule import cosine_schedule
from repro.train.train_step import (init_state, make_optimizer,
                                    make_train_step)

log = logging.getLogger("repro.train")


def train(cfg: ModelConfig, *, seq_len: int, global_batch: int,
          steps: int, ckpt_dir: str | None = None, ckpt_every: int = 50,
          lr: float = 3e-4, seed: int = 0, log_every: int = 10,
          metrics_path: str | None = None,
          fail_at_step: int | None = None):
    """Run (or resume) a training job; returns (final_state, history).

    ``fail_at_step`` injects a crash once (fault-tolerance tests/examples).
    """
    model = Model(cfg)
    opt = make_optimizer(cfg)
    lr_fn = cosine_schedule(lr, warmup=max(steps // 20, 2), total=steps)
    step_fn = jax.jit(make_train_step(model, opt, lr_fn), donate_argnums=0)
    data = SyntheticLM(cfg, seq_len, global_batch, seed=seed)

    state = init_state(model, opt, jax.random.PRNGKey(seed))
    start = 0
    mgr = None
    if ckpt_dir:
        mgr = CheckpointManager(ckpt_dir, keep=2)
        restored, rstep = mgr.restore_latest(state)
        if restored is not None:
            state, start = restored, rstep
            log.info("resumed from step %d", start)

    mon = StragglerMonitor()
    history = []
    failed = {"done": False}
    t_total = time.time()
    for step in range(start, steps):
        if fail_at_step is not None and step == fail_at_step \
                and not failed["done"]:
            failed["done"] = True
            raise RuntimeError(f"injected failure at step {step}")
        batch = data.batch(step, cfg.grad_accum)
        mon.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        mon.stop()
        history.append({"step": step, "loss": loss,
                        "grad_norm": float(metrics["grad_norm"])})
        if step % log_every == 0:
            log.info("step %d loss %.4f", step, loss)
        if mgr and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, state)
    if mgr:
        mgr.save(steps, state)
        mgr.wait()
    if metrics_path:
        os.makedirs(os.path.dirname(metrics_path) or ".", exist_ok=True)
        with open(metrics_path, "w") as f:
            for h in history:
                f.write(json.dumps(h) + "\n")
    log.info("trained %d steps in %.1fs; stragglers=%d",
             steps - start, time.time() - t_total, mon.stragglers)
    return state, history
