"""Functional AdamW with global-norm clipping and low-precision moments.

``moment_dtype=bfloat16`` halves optimizer HBM (needed for the ~790B-param
llama4 config to fit 16 GB/chip at 512 chips — a distributed-scale knob,
see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "OptState"]

OptState = dict[str, Any]


def _global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


@dataclasses.dataclass(frozen=True)
class AdamW:
    """AdamW; ``factored_v=True`` stores the second moment as Adafactor-style
    row/col statistics for ndim>=2 leaves (O(n+m) instead of O(n*m)) — the
    knob that lets ~790B-param configs fit optimizer state in HBM."""
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    factored_v: bool = False

    def _v_init(self, p):
        if self.factored_v and p.ndim >= 2:
            return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                    "c": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return jnp.zeros(p.shape, self.moment_dtype)

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)  # noqa: E731
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(self._v_init, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(self, grads, state: OptState, params, lr) -> tuple[Any, OptState]:
        count = state["count"] + 1
        gnorm = _global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))

        c1 = 1.0 - self.b1 ** count.astype(jnp.float32)
        c2 = 1.0 - self.b2 ** count.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m32 = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            if isinstance(v, dict):                       # factored second
                r = self.b2 * v["r"] + (1 - self.b2) * jnp.mean(g * g, -1)
                c = self.b2 * v["c"] + (1 - self.b2) * jnp.mean(g * g, -2)
                vhat = (r[..., None] * c[..., None, :]
                        / jnp.maximum(jnp.mean(r, -1)[..., None, None], 1e-30))
                new_v = {"r": r, "c": c}
            else:
                v32 = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
                vhat = v32
                new_v = v32.astype(self.moment_dtype)
            step = (m32 / c1) / (jnp.sqrt(vhat / c2) + self.eps)
            step = step + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (new_p.astype(p.dtype), m32.astype(self.moment_dtype),
                    new_v)

        # flatten against the params structure so factored-v dicts stay
        # whole leaves ({"r","c"}) rather than being descended into
        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        res = [upd(p, g, m, v) for p, g, m, v in
               zip(flat_p, flat_g, flat_m, flat_v)]
        new_params = jax.tree_util.tree_unflatten(treedef, [t[0] for t in res])
        new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in res])
        new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in res])
        return new_params, {"m": new_m, "v": new_v, "count": count}
