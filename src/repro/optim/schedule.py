"""LR schedules (pure functions of step)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr
