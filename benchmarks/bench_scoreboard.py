"""Fig. 13 + Sec. 5.9 — static vs dynamic Scoreboard on real-like and
random data across tile row sizes, and the unique-TransRow statistic.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, synth_weights
from repro.core import bitslice
from repro.core.patterns import tile_stats
from repro.core.scoreboard import (dynamic_scoreboard, static_scoreboard,
                                   static_tile_stats)


def _transrows(w, bits, t=8):
    rows = bitslice.transrow_matrix(w, bits, t)       # (S, N, K/t)
    return rows.transpose(2, 1, 0).reshape(-1)


def run():
    t0 = time.perf_counter()
    real = _transrows(synth_weights(1024, 1024, 8, seed=1), 8)
    rand = np.random.default_rng(2).integers(
        0, 256, size=len(real)).astype(np.uint32)

    for label, rows in (("real", real), ("rand", rand)):
        ssi = static_scoreboard(rows, 8)
        uniq = []
        for n in (64, 128, 256, 512, 1024):
            tiles = rows[: (len(rows) // n) * n].reshape(-1, n)
            tiles = tiles[:max(4, 8192 // n)]
            dyn = tile_stats(dynamic_scoreboard(tiles, 8))
            stt = static_tile_stats(ssi, tiles)
            d_dyn = dyn.density.mean()
            d_stat = float(np.mean(np.maximum(stt["ppe"], stt["ape"])
                                   / stt["dense"]))
            emit(f"fig13_{label}_N{n}", 0.0,
                 f"dynamic={d_dyn:.4f} static={d_stat:.4f}")
            if n == 256:
                si = dynamic_scoreboard(tiles, 8)
                uniq.append(si.present.sum(-1).mean())
        emit(f"sec59_unique_{label}", 0.0,
             f"mean_unique_of_256={uniq[0]:.1f} (paper: ~162, real slightly "
             f"lower)")
    emit("fig13_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
