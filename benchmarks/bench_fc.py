"""Fig. 10 — runtime & energy on the FC layers of LLaMA models, all six
accelerators. Weights are synthetic Gaussian-quantized (Sec. 5.9: random vs
real differ by only a few percent); the TA model is driven by the measured
dynamic-scoreboard statistics of those weights.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, synth_weights
from repro.core.costmodel import (AntModel, BitFusionModel, BitVertModel,
                                  OliveModel, TenderModel,
                                  TransitiveArrayModel, sample_subtile_stats)
from repro.core.workloads import llama_fc_gemms

MODELS = ["llama1-7b", "llama1-13b", "llama1-30b", "llama1-65b",
          "llama2-7b", "llama2-13b", "llama3-8b"]


def run(models=None):
    t0 = time.perf_counter()
    prof8 = sample_subtile_stats(synth_weights(2048, 2048, 8), 8,
                                 max_tiles=256)
    prof4 = sample_subtile_stats(synth_weights(2048, 2048, 4), 4,
                                 max_tiles=256)
    baselines = [BitFusionModel(), AntModel(), OliveModel(), BitVertModel()]
    for name in (models or MODELS):
        g8 = llama_fc_gemms(name, w_bits=8)
        g4 = llama_fc_gemms(name, w_bits=4)
        ta8 = TransitiveArrayModel(prof8, 8).run(g8)
        ta4 = TransitiveArrayModel(prof4, 4).run(g4)
        td = TenderModel().run(llama_fc_gemms(name, w_bits=4, a_bits=4))
        parts = []
        for b in baselines:
            r = b.run(g8)
            parts.append(f"{b.name}:x{ta4.speedup_over(r):.2f}/"
                         f"e{r.energy.total / ta4.energy.total:.2f}")
        parts.append(f"tender4:x{ta4.speedup_over(td):.2f}")
        parts.append(f"ta8_vs_olive:x{ta8.speedup_over(OliveModel().run(g8)):.2f}")
        emit(f"fig10_fc_{name}", ta4.seconds * 1e6, " ".join(parts))
    emit("fig10_total", (time.perf_counter() - t0) * 1e6,
         "paper: TA4 vs ANT 4.91x/1.65x, Olive 7.46x/2.31x, BitVert 3.97x/1.65x")


if __name__ == "__main__":
    run()
