"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import os
import time

from benchmarks.common import emit

DRYRUN = os.environ.get("DRYRUN_JSON", "results/dryrun.json")


def run():
    t0 = time.perf_counter()
    if not os.path.exists(DRYRUN):
        emit("roofline", 0.0, f"missing {DRYRUN}; run repro.launch.dryrun")
        return
    recs = [r for r in json.load(open(DRYRUN))
            if r.get("status") == "ok" and r["mesh"] == "16x16"]
    recs.sort(key=lambda r: (r["arch"], r["shape"]))
    for r in recs:
        emit(f"roofline_{r['arch']}_{r['shape']}",
             r["bound_s"] * 1e6,
             f"dom={r['dominant']} comp={r['t_compute_s']:.2e}s "
             f"mem={r['t_memory_s']:.2e}s coll={r['t_collective_s']:.2e}s "
             f"frac={r['roofline_fraction']:.3f} "
             f"useful={r['useful_flops_ratio']:.3f}")
    emit("roofline_total", (time.perf_counter() - t0) * 1e6,
         f"{len(recs)} single-pod cells")


if __name__ == "__main__":
    run()
