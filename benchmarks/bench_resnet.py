"""Fig. 14 / Sec. 5.10 — ResNet-18 (im2col GEMMs) speedups: TA with mixed
4/8-bit vs BitFusion and ANT."""
from __future__ import annotations

import time

from benchmarks.common import emit, synth_weights
from repro.core.costmodel import (AntModel, BitFusionModel,
                                  TransitiveArrayModel, sample_subtile_stats)
from repro.core.workloads import resnet18_gemms


def run():
    t0 = time.perf_counter()
    prof4 = sample_subtile_stats(synth_weights(1024, 1024, 4, seed=5), 4,
                                 max_tiles=128)
    gemms = resnet18_gemms(w_bits=4)
    ta = TransitiveArrayModel(prof4, 4).run(gemms)
    bf = BitFusionModel().run(gemms)
    ant = AntModel().run(gemms)
    emit("fig14_resnet18", ta.seconds * 1e6,
         f"vs_bitfusion:x{ta.speedup_over(bf):.2f} "
         f"vs_ant:x{ta.speedup_over(ant):.2f} (paper: 4.26x / 2.21x)")
    emit("fig14_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
