"""Fig. 11 (TA energy breakdown on LLaMA-1-7B FC) + Table 2 (core areas)."""
from __future__ import annotations

import time

from benchmarks.common import emit, synth_weights
from repro.core.costmodel import (TransitiveArrayModel, core_area_mm2,
                                  sample_subtile_stats)
from repro.core.workloads import llama_fc_gemms


def run():
    t0 = time.perf_counter()
    prof = sample_subtile_stats(synth_weights(2048, 2048, 4, seed=3), 4,
                                max_tiles=256)
    ta = TransitiveArrayModel(prof, 4).run(llama_fc_gemms("llama1-7b",
                                                          w_bits=4))
    e = ta.energy
    emit("fig11_energy_breakdown", ta.seconds * 1e6,
         f"pe={e.pe/e.total:.3f} buffer={e.buffer/e.total:.3f} "
         f"dram={e.dram/e.total:.3f} static={e.static/e.total:.3f} "
         f"(paper: buffer dominates)")
    areas = core_area_mm2()
    for k, v in areas.items():
        emit(f"table2_area_{k}", 0.0, f"{v:.3f} mm2")
    # Sec. 5.8: a static-SI-only TransArray drops the Scoreboard unit
    from repro.core import energy as E
    saved = E.AREA_TA_SCOREBOARD / 1e6 / areas["transarray"]
    emit("sec58_static_area_saving", 0.0,
         f"{saved:.1%} core area without the dynamic Scoreboard "
         f"(paper: ~25%)")
    emit("fig11_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
