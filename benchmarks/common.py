"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time

import numpy as np

__all__ = ["timed", "synth_weights", "emit"]


def timed(fn, *args, reps: int = 3, warmup: int = 1):
    for _ in range(warmup):
        fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    dt = (time.perf_counter() - t0) / reps
    return out, dt * 1e6                      # microseconds


def synth_weights(n: int, k: int, bits: int, seed: int = 0) -> np.ndarray:
    """Gaussian weights quantized to int-``bits`` — stand-in for extracted
    LLaMA tensors. Justified by the paper's own Sec. 5.9 finding that
    random and real data behave within a few percent for TranSparsity."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((n, k))
    scale = np.abs(w).max() / ((1 << (bits - 1)) - 1)
    return np.clip(np.round(w / scale), -(1 << (bits - 1)),
                   (1 << (bits - 1)) - 1).astype(np.int64)


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.2f},{derived}")
