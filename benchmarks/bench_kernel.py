"""Kernel-level microbench (CPU container): (a) op-count ratios of the
transitive dataflow vs dense / bit-sparse accumulation — the paper's actual
speedup source; (b) wall-clock of the batched multi-tile engine
(core/engine.py) vs the seed per-tile Python-loop walker
(core/transitive_ref.py), split into plan (offline) and run (online);
(c) interpret-mode correctness timing of the Pallas kernels; (d) HLO
flops/bytes of the W4A8 MXU path vs a bf16 matmul at equal shape (the
TPU-side memory win).

``--smoke`` shrinks every shape for CI: a few seconds total, still
exercising every code path end-to-end. ``--serve-bench`` switches to the
cached-vs-uncached serving comparison (plan built per call vs plan from
core/plancache.py) and writes ``BENCH_engine.json``; the kernel microbench
is then skipped (CI runs the two as separate steps). The serving bench
enumerates the **backend registry** (core/backend.py) — one keyed entry
per backend under ``"backends"`` in the JSON (e.g.
``engine_jit.device_decode_us``) — so the perf trajectory distinguishes
backends instead of overwriting one flat dict. Device-resident backends
additionally get a ``mesh_decode_us`` series: the same decode through the
multi-device serve cell (batch sharded ``P("data")``, DevicePlans placed
on the mesh) over the largest data extent that divides the decode batch —
1 on a plain host, 4 in the CI forced-multi-device leg.
"""
from __future__ import annotations

import argparse
import json
import time
import warnings

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, synth_weights, timed
from repro.core.engine import BatchedTransitiveEngine
from repro.core.transitive import transitive_gemm_stats
from repro.core.transitive_ref import transitive_gemm_ref
from repro.kernels import ops


def run(smoke: bool = False):
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)

    # (a) op-count ratios (N=256-row sub-tiles, T=8, int8 weights)
    na = 64 if smoke else 256
    w = synth_weights(na, na, 8, seed=0)
    x = rng.integers(-128, 128, (na, 32))
    _, tot = transitive_gemm_stats(w, x, 8, 8)
    emit("kernel_opcount", 0.0,
         f"dense={tot['dense_ops']} bit={tot['bit_ops']} "
         f"transitive={max(tot['ppe_ops'], tot['ape_ops'])} "
         f"reduction_vs_dense=x{tot['dense_ops']/max(tot['ppe_ops'], tot['ape_ops']):.2f} "
         f"(paper: 8x at T=8)")

    # (b) batched engine vs seed per-tile walker (ISSUE 1 acceptance:
    # >= 5x on 256x256x256 int8; plan is reusable across activations)
    nb = 64 if smoke else 256
    w = synth_weights(nb, nb, 8, seed=1)
    x = rng.integers(-128, 128, (nb, nb))
    eng = BatchedTransitiveEngine(bits=8, t=8)
    plan, us_plan = timed(lambda: eng.plan(w), reps=1)
    out_run, us_run = timed(lambda: eng.run(plan, x), reps=1)
    _, us_e2e = timed(lambda: eng(w, x), reps=1)
    ref, us_ref = timed(lambda: transitive_gemm_ref(w, x, 8, 8),
                        reps=1, warmup=0)
    np.testing.assert_array_equal(out_run, ref)
    np.testing.assert_array_equal(out_run,
                                  w.astype(np.int64) @ x.astype(np.int64))
    emit("kernel_engine_vs_ref", us_e2e,
         f"{nb}x{nb}x{nb} int8 T=8: ref={us_ref:.0f}us plan={us_plan:.0f}us "
         f"run={us_run:.0f}us speedup_e2e=x{us_ref/us_e2e:.1f} "
         f"speedup_run=x{us_ref/us_run:.1f} (floor: 5x)")

    # (c) interpret-mode kernel wall-times (correctness path, not perf)
    mc, nc, kc = (16, 8, 64) if smoke else (128, 64, 256)
    qx = jnp.asarray(rng.integers(-128, 128, (mc, kc)), jnp.int8)
    qw = jnp.asarray(synth_weights(nc, kc, 4), jnp.int8)
    _, us = timed(lambda: jax.block_until_ready(
        ops.transitive_gemm(qx, qw, w_bits=4, t=8)))
    emit("kernel_transitive_interpret", us,
         f"{mc}x{nc}x{kc} w4 (interpret mode)")

    if not smoke:
        sx = jnp.ones((128, 1), jnp.float32)
        sg = jnp.ones((64, 2), jnp.float32)
        _, us = timed(lambda: jax.block_until_ready(
            ops.w4a8_gemm(qx, sx, qw, sg, group=128)))
        emit("kernel_w4a8_interpret", us, "128x64x256 (interpret mode)")

        # (d) dry-lowered flops/bytes: W4A8 int path vs bf16 dense
        m, n, k = 256, 512, 1024
        def int_path(qx, qw):
            return jax.lax.dot_general(qx, qw, (((1,), (1,)), ((), ())),
                                       preferred_element_type=jnp.int32)
        def bf16_path(a, b):
            return a @ b.T
        def cost(ca):
            # old jax returns a per-device list of dicts, new jax one dict
            return ca[0] if isinstance(ca, (list, tuple)) else ca
        ca_int = cost(jax.jit(int_path).lower(
            jax.ShapeDtypeStruct((m, k), jnp.int8),
            jax.ShapeDtypeStruct((n, k), jnp.int8)).compile().cost_analysis())
        ca_bf = cost(jax.jit(bf16_path).lower(
            jax.ShapeDtypeStruct((m, k), jnp.bfloat16),
            jax.ShapeDtypeStruct((n, k), jnp.bfloat16)).compile().cost_analysis())
        emit("kernel_w4a8_vs_bf16_bytes", 0.0,
             f"int8_bytes={ca_int.get('bytes accessed', 0):.0f} "
             f"bf16_bytes={ca_bf.get('bytes accessed', 0):.0f} "
             f"ratio={ca_bf.get('bytes accessed', 1)/max(ca_int.get('bytes accessed', 1),1):.2f}x")
    emit("kernel_total", (time.perf_counter() - t0) * 1e6,
         "smoke" if smoke else "ok")


def serve_engine_bench(smoke: bool = False, backend: str = "engine_jit",
                       mesh=None) -> dict:
    """Continuous-batching throughput/latency series (repro.serve).

    Drives the paged-KV :class:`ServeEngine` over staggered arrivals with
    shared prompt prefixes on the reduced smollm config and reports
    aggregate tokens/s, per-request TTFT/latency, and a per-step
    cumulative-token series — the request-level counterpart of the
    per-backend GEMM decode series. Lands under ``"serve_engine"`` in
    BENCH_engine.json (``serve_engine.tokens_per_s`` is the trajectory
    key)."""
    from repro.configs import get_reduced
    from repro.core.backend import get_backend
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.serve import ServeEngine

    cfg = serve_config(get_reduced("smollm_135m").replace(
        n_layers=2 if smoke else 4), backend=backend)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = get_backend(backend)
    if b.needs_plan:
        model.precompile_plans(params)
        if b.device_resident:
            params = model.attach_device_plans(params, mesh=mesh)
    rng = np.random.default_rng(3)
    plen, gen, n_req = (8, 4, 4) if smoke else (16, 16, 8)
    base = rng.integers(0, cfg.vocab, size=plen).tolist()
    # every other request extends the shared base prompt — the prefix trie
    # should serve those pages instead of re-prefilling them
    prompts = [list(base) if i % 2 == 0 else
               base[:plen // 2] + rng.integers(
                   0, cfg.vocab, size=plen - plen // 2).tolist()
               for i in range(n_req)]
    page_size = 4
    max_len = -(-(plen + gen) // page_size) * page_size
    eng = ServeEngine(model, params, n_slots=2 if smoke else 4,
                      max_len=max_len, page_size=page_size, mesh=mesh)
    series = []
    submitted = host_step = 0
    arrive_every = 2                        # staggered arrivals
    t0 = time.perf_counter()
    while submitted < n_req or eng.queue or eng.active:
        if submitted < n_req and host_step >= submitted * arrive_every:
            eng.submit(prompts[submitted], gen)
            submitted += 1
        eng.step()
        host_step += 1
        done = (sum(len(r.out) for r in eng.finished)
                + sum(len(r.out) for r in eng.active.values()))
        series.append({"t_s": time.perf_counter() - t0, "tokens": done})
    rep = eng.report()
    emit("serve_engine", rep["wall_s"] * 1e6,
         f"{backend}: {rep['n_requests']} reqs x {gen} tokens "
         f"(prompt {plen}) staggered -> {rep['tokens_per_s']:.1f} tok/s "
         f"(prefix hits={rep['counters']['prefix_hits']} "
         f"pages shared={rep['counters']['pages_shared']} "
         f"prefill skipped={rep['counters']['prefill_skipped']})")
    return {"backend": backend, "prompt_len": plen, "gen": gen,
            "n_requests": rep["n_requests"],
            "total_tokens": rep["total_tokens"],
            "wall_s": rep["wall_s"],
            "tokens_per_s": rep["tokens_per_s"],
            "ttft_s": [r["ttft_s"] for r in rep["requests"]],
            "latency_s": [r["latency_s"] for r in rep["requests"]],
            "series": series,
            "counters": {k: rep["counters"][k] for k in
                         ("prefix_hits", "pages_shared", "prefill_skipped",
                          "prefill_computed", "decode_steps",
                          "admitted", "completed")}}


def serve_fastpath_bench(smoke: bool = False,
                         backend: str = "engine_jit") -> dict:
    """The PR-8 serve fast paths as curves, not points.

    (a) ``paged_kernel``: a ``max_len`` sweep timing one packed decode
    step through the full-extent gather oracle vs the Pallas live-page
    kernel at a FIXED small live-page count — the gather cost grows with
    ``max_len`` while the kernel cost tracks live pages — plus
    engine-level tokens/s for both paths at the largest swept ``max_len``.
    (b) ``prefill_bucketed``: the same staggered workload with bucketing
    on vs off, reporting distinct prefill jit specializations and bucket
    hits. Lands under ``serve_engine.paged_kernel`` /
    ``serve_engine.prefill_bucketed`` in BENCH_engine.json.
    """
    from repro.configs import get_reduced
    from repro.core.backend import get_backend
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.serve import ServeEngine

    cfg = serve_config(get_reduced("smollm_135m").replace(
        n_layers=2 if smoke else 4), backend=backend)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = get_backend(backend)
    if b.needs_plan:
        model.precompile_plans(params)
        if b.device_resident:
            params = model.attach_device_plans(params)

    # page_size 16 and a deep max_len sweep: the gather oracle's per-step
    # K/V materialization is O(max_len) while the kernel touches only the
    # fixed live pages (its residual growth is the full-extent softmax +
    # page-table scan) — the curves separate visibly from ~512 up
    page_size = 16
    n_slots = 4
    live_pages = 2                      # steps fixed -> kernel cost fixed
    sweep = (256, 512) if smoke else (512, 2048, 8192)
    iters = 3 if smoke else 10
    dstep = jax.jit(model.decode_step_paged, static_argnames=("kernel",))
    curve = []
    for max_len in sweep:
        pps = max_len // page_size
        pool = model.init_page_pool(n_slots * pps + 1, page_size)
        table = np.zeros((n_slots, pps), np.int32)
        for s in range(n_slots):
            table[s, :live_pages] = [s * live_pages + 1 + j
                                     for j in range(live_pages)]
        steps = jnp.full((n_slots,), live_pages * page_size - 1, jnp.int32)
        toks = jnp.ones((n_slots, 1), jnp.int32)
        tbl = jnp.asarray(table)
        entry = {"max_len": max_len, "live_pages": live_pages}
        for kern, key in ((False, "gather_decode_us"),
                          (True, "kernel_decode_us")):
            lg, _ = dstep(params, pool, toks, tbl, steps, kernel=kern)
            jax.block_until_ready(lg)   # compile outside the timed loop
            t0 = time.perf_counter()
            for _ in range(iters):
                lg, _ = dstep(params, pool, toks, tbl, steps, kernel=kern)
                jax.block_until_ready(lg)
            entry[key] = (time.perf_counter() - t0) * 1e6 / iters
        curve.append(entry)
        emit("serve_engine.paged_kernel", entry["kernel_decode_us"],
             f"max_len={max_len} live_pages={live_pages}: "
             f"gather={entry['gather_decode_us']:.0f}us "
             f"kernel={entry['kernel_decode_us']:.0f}us "
             f"(x{entry['gather_decode_us']/entry['kernel_decode_us']:.1f})")

    # engine-level throughput at the largest swept max_len, both paths +
    # bucketing on/off for the specialization counts
    max_len = sweep[-1]
    rng = np.random.default_rng(5)
    plen, gen, n_req = (6, 6, 4) if smoke else (8, 24, 6)
    prompts = [rng.integers(0, cfg.vocab, size=3 + (i * 5) % (plen - 2)
                            + 1).tolist() for i in range(n_req)]
    tput = {}
    bucketed = {}
    for kern, bucket_on in ((False, False), (True, True)):
        eng = ServeEngine(model, params, n_slots=n_slots, max_len=max_len,
                          page_size=page_size, paged_kernel=kern,
                          bucket_prefill=bucket_on)
        submitted = host_step = 0
        while submitted < n_req or eng.queue or eng.active:
            if submitted < n_req and host_step >= submitted * 2:
                eng.submit(prompts[submitted], gen)
                submitted += 1
            eng.step()
            host_step += 1
        rep = eng.report()
        st = eng.stats()
        key = "fastpath" if kern else "oracle"
        tput[f"tokens_per_s_{key}"] = rep["tokens_per_s"]
        bucketed["bucketed" if bucket_on else "per_request"] = {
            "prefill_traces": st["prefill_traces"],
            "prefill_calls": st["prefill_calls"],
            "prefill_batched_calls": st["prefill_batched_calls"],
            "bucket_hits": st["bucket_hits"],
            "prefill_pad_rows": st["prefill_pad_rows"]}
    emit("serve_engine.prefill_bucketed", 0.0,
         f"max_len={max_len} {n_req} reqs: "
         f"traces per-request={bucketed['per_request']['prefill_traces']} "
         f"bucketed={bucketed['bucketed']['prefill_traces']} "
         f"bucket_hits={bucketed['bucketed']['bucket_hits']} | tok/s "
         f"oracle={tput['tokens_per_s_oracle']:.1f} "
         f"fastpath={tput['tokens_per_s_fastpath']:.1f}")
    return {"paged_kernel": {"page_size": page_size, "n_slots": n_slots,
                             "sweep": curve, **tput},
            "prefill_bucketed": bucketed}


def serve_hotswap_bench(smoke: bool = False,
                        backend: str = "engine_jit") -> dict:
    """Live-weight swap cost as a timeline, not a point (PR 9).

    Serves the same two-phase workload twice on the reduced smollm
    config: **hot** — the fleet path, where generation 1 is built
    off-path (``repro.fleet.build_generation``) and atomically swapped
    between decode steps — and **drain_restart** — the pre-fleet
    baseline, where the engine drains, the process pays the cold plan
    build inline, and a new engine starts. Both runs record per-step
    decode wall times; the headline is the worst inter-step stall around
    the weight change (``stall_hot_us`` vs ``stall_restart_us`` — the
    hot one should be a normal step, the restart one IS the plan build).
    Also times the bundle pipeline on the same weights:
    ``bundle_write_us`` (planner, amortised once per fleet) vs
    ``bundle_load_us`` (per serve cell, fresh cache, zero plan builds)
    vs ``plan_build_us`` (what the cell pays without bundles). Lands
    under ``serve_engine.hotswap`` in BENCH_engine.json."""
    import shutil
    import tempfile

    from repro.configs import get_reduced
    import repro.core.plancache as PC
    from repro.core.plancache import PlanCache
    from repro.fleet import build_generation, load_bundles, write_bundles
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.serve import ServeEngine

    cfg = serve_config(get_reduced("smollm_135m").replace(
        n_layers=2 if smoke else 4), backend=backend)
    model = Model(cfg)
    raw = {g: model.init(jax.random.PRNGKey(g)) for g in (0, 1)}
    rng = np.random.default_rng(5)
    plen, gen_toks, n_req = (8, 6, 4) if smoke else (16, 16, 8)
    prompts = [rng.integers(0, cfg.vocab, size=plen).tolist()
               for _ in range(n_req)]
    first = n_req // 2
    page_size = 4
    max_len = -(-(plen + gen_toks) // page_size) * page_size

    def _run(eng, reqs, series, swap_to=None, swap_at=2):
        """Drive reqs to completion, appending per-step wall times;
        optionally stage a pre-built generation after ``swap_at`` steps."""
        submitted = 0
        swapped = None
        while submitted < len(reqs) or eng.queue or eng.active:
            if submitted < len(reqs):
                eng.submit(reqs[submitted], gen_toks)
                submitted += 1
            if swap_to is not None and swapped is None \
                    and len(series) >= swap_at:
                swapped = eng.swap_params(swap_to.params, tag="bench")
            t0 = time.perf_counter()
            eng.step()
            series.append({"step_us": (time.perf_counter() - t0) * 1e6,
                           "generation": eng.generation})
        return swapped

    # -- hot: generation 1 built off-path, swapped between steps ----------
    cache = PlanCache(capacity=256)
    prev = PC.set_default_cache(cache)
    try:
        gen0 = build_generation(model, raw[0], gen=0)
        t0 = time.perf_counter()
        gen1 = build_generation(model, raw[1], ref=gen0.params, gen=1)
        plan_build_us = (time.perf_counter() - t0) * 1e6

        hot: list[dict] = []
        eng = ServeEngine(model, gen0.params, n_slots=2, max_len=max_len,
                          page_size=page_size)
        _run(eng, prompts[:first], hot)       # warm the jits on gen 0
        warm = len(hot)
        _run(eng, prompts[first:], hot, swap_to=gen1)
        swap_step = next(i for i, s in enumerate(hot)
                         if s["generation"] > 0)
        stall_hot_us = max(s["step_us"] for s in hot[warm:])
        hot_traces = eng.stats()["decode_jit_traces"]

        # -- drain-and-restart baseline: cold build inline ----------------
        restart: list[dict] = []
        eng = ServeEngine(model, gen0.params, n_slots=2, max_len=max_len,
                          page_size=page_size)
        _run(eng, prompts[:first], restart)   # drains completely
        t0 = time.perf_counter()
        PC.set_default_cache(PlanCache(capacity=256))   # cold process
        gen1_cold = build_generation(model, raw[1], gen=1)
        eng = ServeEngine(model, gen1_cold.params, n_slots=2,
                          max_len=max_len, page_size=page_size)
        stall_restart_us = (time.perf_counter() - t0) * 1e6
        restart.append({"step_us": stall_restart_us, "generation": 1,
                        "restart_gap": True})
        _run(eng, prompts[first:], restart)
    finally:
        PC.set_default_cache(prev)

    # -- bundles: plan once (planner), load on a fresh cell ---------------
    bdir = tempfile.mkdtemp(prefix="hotswap_bundles_")
    try:
        t0 = time.perf_counter()
        write_bundles(raw[1], cfg.quant, bdir)
        bundle_write_us = (time.perf_counter() - t0) * 1e6
        cell_cache = PlanCache(capacity=256)
        prev = PC.set_default_cache(cell_cache)
        try:
            t0 = time.perf_counter()
            load_bundles(raw[1], cfg.quant, bdir)
            bundle_load_us = (time.perf_counter() - t0) * 1e6
        finally:
            PC.set_default_cache(prev)
        if cell_cache.stats()["misses"]:
            raise RuntimeError("bundle load built plans on the serve "
                               f"cell: {cell_cache.stats()}")
    finally:
        shutil.rmtree(bdir, ignore_errors=True)

    emit("serve_engine.hotswap", stall_hot_us,
         f"{backend}: swap stall hot={stall_hot_us:.0f}us vs "
         f"drain+restart={stall_restart_us:.0f}us "
         f"(x{stall_restart_us / max(stall_hot_us, 1):.1f}) | "
         f"decode traces through swap={hot_traces} | plan_build="
         f"{plan_build_us:.0f}us bundle_write={bundle_write_us:.0f}us "
         f"bundle_load={bundle_load_us:.0f}us")
    return {"backend": backend, "n_requests": n_req, "gen": gen_toks,
            "swap_step": swap_step,
            "stall_hot_us": stall_hot_us,
            "stall_restart_us": stall_restart_us,
            "decode_jit_traces_hot": hot_traces,
            "plan_build_us": plan_build_us,
            "bundle_write_us": bundle_write_us,
            "bundle_load_us": bundle_load_us,
            "timeline_hot": hot,
            "timeline_restart": restart}


def serve_bench(smoke: bool = False, out: str = "BENCH_engine.json",
                backends=None):
    """Cached vs uncached serving + a per-backend decode series.

    The headline pair stays what it was: *uncached* is the
    pre-plan-cache serving behaviour (every forward call re-plans the
    weight), *cached* is the plan-cached host engine (plans built once
    offline via PlanCache, decode run-only). Then every registered
    backend (``repro.core.backend`` — or the ``backends`` subset) decodes
    the same weights through its own ``execute`` path under jit, plans
    and DevicePlans prepared offline, and the JSON gains one keyed entry
    per backend under ``"backends"`` — ``engine_jit.device_decode_us``
    next to ``engine.callback_decode_us`` next to ``int_dot.decode_us`` —
    so the CI perf trajectory distinguishes backends instead of
    overwriting one flat dict. Every series is guarded bit-exact against
    the int64 GEMM before its numbers are emitted."""
    from repro.core.backend import EngineConfig, get_backend, list_backends
    import repro.core.plancache as PC
    from repro.core.plancache import PlanCache

    names = list(backends) if backends else [
        nm for nm in list_backends() if get_backend(nm).cpu_ok]
    layers, steps = (4, 8) if smoke else (8, 32)
    n = k = 64 if smoke else 256
    m = 4                                    # decode-like tall-skinny GEMM
    ecfg = EngineConfig(w_bits=8, t=8, groups=1)
    rng = np.random.default_rng(2)
    # int8 like the serving path (the cache canonicalises dtype before
    # fingerprinting, so every series shares one entry per weight either
    # way; the misses guard below would catch a regression)
    ws = [synth_weights(n, k, 8, seed=s).astype(np.int8)
          for s in range(layers)]
    xs = [rng.integers(-128, 128, (k, m)) for _ in range(steps)]
    wants0 = [xs[0].T.astype(np.int64) @ w.astype(np.int64).T
              for w in ws]                   # (M, N) int64 guard truth
    eng = BatchedTransitiveEngine(bits=8, t=8)

    t0 = time.perf_counter()
    for x in xs:
        for w in ws:
            eng(w, x)                        # plan + run, every call
    us_uncached = (time.perf_counter() - t0) * 1e6

    cache = PlanCache(capacity=2 * layers)
    t0 = time.perf_counter()
    for w in ws:                             # offline precompile
        cache.get_or_build(w, ecfg)
    us_plan = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    for x in xs:
        for w in ws:                         # hot path: run-only
            cache.run(w, x, ecfg)
    us_cached = (time.perf_counter() - t0) * 1e6

    stats = cache.stats()
    # fail loudly even under python -O: a re-plan in the cached loop would
    # make the emitted numbers meaningless
    if stats["misses"] != layers or stats["hits"] != layers * steps:
        raise RuntimeError(f"plan cache re-planned during the cached loop: "
                           f"{stats} (expected misses={layers}, "
                           f"hits={layers * steps})")
    calls = layers * steps
    result = {
        "shape": {"layers": layers, "decode_steps": steps,
                  "n": n, "k": k, "m": m, "w_bits": 8, "t": 8},
        "uncached_us": us_uncached,
        "plan_build_us": us_plan,
        "cached_decode_us": us_cached,
        "per_call_uncached_us": us_uncached / calls,
        "per_call_cached_us": us_cached / calls,
        "speedup_cached": us_uncached / us_cached,
        "backends": {},
    }

    # per-backend decode series: same weights, each backend's own execute
    # path under jit. The engine host callbacks resolve plans from our warm
    # cache (swapped in as the process default for the duration).
    prev = PC.set_default_cache(cache)
    try:
        xs_row = [jnp.asarray(x.T, jnp.int8) for x in xs]      # (M, K)
        qws = [jnp.asarray(w, jnp.int8) for w in ws]
        for name in names:
            b = get_backend(name)
            entry: dict[str, float] = {}
            plans = [None] * layers
            dplans = [None] * layers
            if b.needs_plan:
                plans = [cache.get_or_build(w, ecfg, backend=name)
                         for w in ws]        # warm: all hits
            if b.needs_plan and b.device_resident:
                t0 = time.perf_counter()
                dplans = [cache.get_or_build_device(w, ecfg, backend=name)
                          for w in ws]
                entry["device_plan_compile_us"] = \
                    (time.perf_counter() - t0) * 1e6
            fns = [jax.jit(lambda a, _b=b, _w=qws[i], _p=plans[i],
                           _d=dplans[i]: _b.execute(a, _w, _p, _d, ecfg))
                   for i in range(layers)]
            # bit-exact guard before timing: int32 ≡ int64 mod 2^32 (smoke
            # magnitudes don't overflow) — a wrong number here would make
            # the emitted series meaningless
            for i, f in enumerate(fns):
                np.testing.assert_array_equal(
                    np.asarray(f(xs_row[0])), wants0[i])
            t0 = time.perf_counter()
            for qx in xs_row:
                for f in fns:
                    jax.block_until_ready(f(qx))
            us_decode = (time.perf_counter() - t0) * 1e6
            decode_key = ("device_decode_us" if b.device_resident
                          and b.needs_plan else
                          "callback_decode_us" if b.needs_plan else
                          "decode_us")
            entry[decode_key] = us_decode
            entry["per_call_us"] = us_decode / calls

            if b.device_resident:
                # the multi-device serve cell's decode: batch sharded
                # P("data") over the widest data extent dividing it, plan
                # leaves placed on the mesh (replicated — the serve-cell
                # default). On a plain 1-device host the extent is 1 (the
                # code path still runs end-to-end); the CI forced-multi-
                # device leg produces the real N-way number.
                from jax.sharding import (Mesh, NamedSharding,
                                          PartitionSpec as P)
                from repro.core.backend import shard_device_plan
                mesh_n = max(d for d in
                             range(1, min(len(jax.devices()), m) + 1)
                             if m % d == 0)
                mesh = Mesh(np.asarray(jax.devices()[:mesh_n]), ("data",))
                mdplans = [shard_device_plan(d, mesh) if d is not None
                           else None for d in dplans]
                xs_mesh = [jax.device_put(
                    qx, NamedSharding(mesh, P("data", None)))
                    for qx in xs_row]
                mfns = [jax.jit(lambda a, _b=b, _w=qws[i], _p=plans[i],
                                _d=mdplans[i]: _b.execute(a, _w, _p, _d,
                                                          ecfg))
                        for i in range(layers)]
                for i, f in enumerate(mfns):
                    np.testing.assert_array_equal(
                        np.asarray(f(xs_mesh[0])), wants0[i])
                t0 = time.perf_counter()
                for qx in xs_mesh:
                    for f in mfns:
                        jax.block_until_ready(f(qx))
                entry["mesh_decode_us"] = (time.perf_counter() - t0) * 1e6
                entry["mesh_devices"] = mesh_n
            result["backends"][name] = entry
    finally:
        PC.set_default_cache(prev)

    # every series must have run against the plans built above — any new
    # miss means a fingerprint diverged and the comparison is meaningless
    if cache.stats()["misses"] != layers:
        raise RuntimeError(
            f"a backend series re-planned: {cache.stats()} "
            f"(expected misses={layers})")
    result["cache"] = cache.stats()

    # continuous-batching engine: request-level throughput next to the
    # GEMM-level decode series (acceptance key: serve_engine.tokens_per_s)
    result["serve_engine"] = serve_engine_bench(smoke=smoke)

    # PR-8 fast paths: live-page kernel max_len sweep + bucketed-prefill
    # specialization counts (serve_engine.paged_kernel.* /
    # serve_engine.prefill_bucketed.*)
    result["serve_engine"].update(serve_fastpath_bench(smoke=smoke))

    # PR-9 live-weight serving: hot-swap stall timeline vs drain-and-
    # restart + the bundle pipeline costs (serve_engine.hotswap.*)
    result["serve_engine"]["hotswap"] = serve_hotswap_bench(smoke=smoke)

    # static-analysis gate overhead (ISSUE 10): verify one plan + its
    # lowering, and cost one decode jaxpr — the work the publish gates
    # add per cold build. Tracked so the gates stay off the hot path
    # (they run once per plan build / bundle load / swap, never per
    # decode step).
    import timeit as _timeit

    from repro.analysis.costcheck import jaxpr_cost
    from repro.analysis.planlint import verify_device_plan, verify_plan
    from repro.core.backend import get_backend as _get_backend
    _plan = cache.get_or_build(ws[0], ecfg)
    _dev = _get_backend("engine_jit").compile(_plan)
    _n = 3
    _lint_s = _timeit.timeit(
        lambda: (verify_plan(_plan), verify_device_plan(_dev, _plan)),
        number=_n) / _n
    _w32 = jnp.asarray(ws[0], jnp.int32)
    _jx = jax.make_jaxpr(
        lambda x: jnp.einsum("bk,nk->bn", x, _w32)
    )(jnp.ones((4, k), jnp.int8))
    _cost_s = _timeit.timeit(lambda: jaxpr_cost(_jx), number=_n) / _n
    result["analysis"] = {"planlint_us": _lint_s * 1e6,
                          "costcheck_us": _cost_s * 1e6}

    # legacy flat aliases for the PR-2/PR-3 trajectory keys
    eng_e = result["backends"].get("engine", {})
    eng_j = result["backends"].get("engine_jit", {})
    if "callback_decode_us" in eng_e:
        result["callback_decode_us"] = eng_e["callback_decode_us"]
        result["per_call_callback_us"] = eng_e["per_call_us"]
    if "device_decode_us" in eng_j:
        result["device_plan_compile_us"] = eng_j["device_plan_compile_us"]
        result["device_decode_us"] = eng_j["device_decode_us"]
        result["per_call_device_us"] = eng_j["per_call_us"]
        result["speedup_device_vs_cached"] = \
            us_cached / eng_j["device_decode_us"]
        if "callback_decode_us" in eng_e:
            result["speedup_device_vs_callback"] = \
                eng_e["callback_decode_us"] / eng_j["device_decode_us"]

    with open(out, "w") as f:
        json.dump(result, f, indent=2)
    per_backend = " ".join(
        f"{nm}={e.get('device_decode_us', e.get('callback_decode_us', e.get('decode_us', 0.0))):.0f}us"
        for nm, e in result["backends"].items())
    emit("serve_plan_cache", us_cached,
         f"{layers} layers x {steps} steps {n}x{k}x{m}: "
         f"uncached={us_uncached:.0f}us plan_once={us_plan:.0f}us "
         f"cached_decode={us_cached:.0f}us "
         f"speedup=x{result['speedup_cached']:.1f} | {per_backend} "
         f"(misses={stats['misses']} hits={stats['hits']}) -> {out}")


if __name__ == "__main__":
    from repro.core.backend import list_backends
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes for CI (seconds, not minutes)")
    ap.add_argument("--serve-bench", action="store_true",
                    help="run ONLY the cached-vs-uncached serving benchmark "
                    "(the kernel microbench is its own invocation)")
    ap.add_argument("--json", default="BENCH_engine.json",
                    help="output path for the serving-bench JSON")
    ap.add_argument("--backends", default=None,
                    help="comma-separated registry backend names for the "
                    "serve-bench decode series (default: every CPU-capable "
                    f"registered backend: {','.join(list_backends())})")
    ap.add_argument("--path", default=None,
                    choices=("engine", "engine_jit"),
                    help="DEPRECATED alias: 'engine' = host series only, "
                    "'engine_jit' = host + device series (use --backends)")
    args = ap.parse_args()
    backends = args.backends.split(",") if args.backends else None
    if args.path is not None and backends is None:
        warnings.warn("--path is deprecated; use --backends",
                      DeprecationWarning)
        backends = (["engine"] if args.path == "engine"
                    else ["engine", "engine_jit"])
    if args.serve_bench:
        serve_bench(smoke=args.smoke, out=args.json, backends=backends)
    else:
        run(smoke=args.smoke)
