"""Kernel-level microbench (CPU container): (a) op-count ratios of the
transitive dataflow vs dense / bit-sparse accumulation — the paper's actual
speedup source; (b) interpret-mode correctness timing of the Pallas kernels;
(c) HLO flops/bytes of the W4A8 MXU path vs a bf16 matmul at equal shape
(the TPU-side memory win).
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, synth_weights, timed
from repro.core.transitive import transitive_gemm_stats
from repro.kernels import ops


def run():
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)

    # (a) op-count ratios (N=256-row sub-tiles, T=8, int8 weights)
    w = synth_weights(256, 256, 8, seed=0)
    x = rng.integers(-128, 128, (256, 32))
    _, tot = transitive_gemm_stats(w, x, 8, 8)
    emit("kernel_opcount", 0.0,
         f"dense={tot['dense_ops']} bit={tot['bit_ops']} "
         f"transitive={max(tot['ppe_ops'], tot['ape_ops'])} "
         f"reduction_vs_dense=x{tot['dense_ops']/max(tot['ppe_ops'], tot['ape_ops']):.2f} "
         f"(paper: 8x at T=8)")

    # (b) interpret-mode kernel wall-times (correctness path, not perf)
    qx = jnp.asarray(rng.integers(-128, 128, (128, 256)), jnp.int8)
    qw = jnp.asarray(synth_weights(64, 256, 4), jnp.int8)
    _, us = timed(lambda: jax.block_until_ready(
        ops.transitive_gemm(qx, qw, w_bits=4, t=8)))
    emit("kernel_transitive_interpret", us, "128x64x256 w4 (interpret mode)")

    sx = jnp.ones((128, 1), jnp.float32)
    sg = jnp.ones((64, 2), jnp.float32)
    _, us = timed(lambda: jax.block_until_ready(
        ops.w4a8_gemm(qx, sx, qw, sg, group=128)))
    emit("kernel_w4a8_interpret", us, "128x64x256 (interpret mode)")

    # (c) dry-lowered flops/bytes: W4A8 int path vs bf16 dense
    m, n, k = 256, 512, 1024
    def int_path(qx, qw):
        return jax.lax.dot_general(qx, qw, (((1,), (1,)), ((), ())),
                                   preferred_element_type=jnp.int32)
    def bf16_path(a, b):
        return a @ b.T
    ca_int = jax.jit(int_path).lower(
        jax.ShapeDtypeStruct((m, k), jnp.int8),
        jax.ShapeDtypeStruct((n, k), jnp.int8)).compile().cost_analysis()
    ca_bf = jax.jit(bf16_path).lower(
        jax.ShapeDtypeStruct((m, k), jnp.bfloat16),
        jax.ShapeDtypeStruct((n, k), jnp.bfloat16)).compile().cost_analysis()
    emit("kernel_w4a8_vs_bf16_bytes", 0.0,
         f"int8_bytes={ca_int.get('bytes accessed', 0):.0f} "
         f"bf16_bytes={ca_bf.get('bytes accessed', 0):.0f} "
         f"ratio={ca_bf.get('bytes accessed', 1)/max(ca_int.get('bytes accessed', 1),1):.2f}x")
    emit("kernel_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
