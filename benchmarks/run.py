"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Usage:
  PYTHONPATH=src python -m benchmarks.run [section ...]
"""
from __future__ import annotations

import sys
import time

from benchmarks import (bench_attention, bench_dse, bench_energy_area,
                        bench_fc, bench_kernel, bench_resnet,
                        bench_roofline, bench_scoreboard)

SECTIONS = {
    "dse": bench_dse.run,                # Fig. 9
    "fc": bench_fc.run,                  # Fig. 10
    "energy_area": bench_energy_area.run,  # Fig. 11 + Tbl. 2
    "attention": bench_attention.run,    # Fig. 12
    "scoreboard": bench_scoreboard.run,  # Fig. 13 + Sec. 5.9
    "resnet": bench_resnet.run,          # Fig. 14
    "kernel": bench_kernel.run,          # kernels + TPU memory story
    "roofline": bench_roofline.run,      # EXPERIMENTS.md §Roofline
}


def main() -> None:
    picks = sys.argv[1:] or list(SECTIONS)
    print("name,us_per_call,derived")
    t0 = time.perf_counter()
    for name in picks:
        SECTIONS[name]()
    print(f"all,{(time.perf_counter()-t0)*1e6:.0f},sections={picks}")


if __name__ == "__main__":
    main()
