"""Fig. 12 — Attention-layer speedups over BitFusion (seq 2048).

K/V caches act as dynamically-generated weights — only TA's dynamic
scoreboard (and ANT/BitFusion) support them; TA/ANT run 8-bit group-wise,
BitFusion 16-bit (Sec. 5.7).
"""
from __future__ import annotations

import time

from benchmarks.common import emit, synth_weights
from repro.core.costmodel import (AntModel, BitFusionModel, Gemm,
                                  TransitiveArrayModel, sample_subtile_stats)
from repro.core.workloads import llama_attention_gemms


def run():
    t0 = time.perf_counter()
    prof8 = sample_subtile_stats(synth_weights(2048, 2048, 8, seed=7), 8,
                                 max_tiles=256)
    for name in ("llama1-7b", "llama2-7b", "llama3-8b"):
        att8 = llama_attention_gemms(name, bits=8)
        att16 = [Gemm(g.n, g.k, g.m, 16, 16, g.name) for g in att8]
        ta = TransitiveArrayModel(prof8, 8).run(att8)
        ant = AntModel().run(att8)
        bf = BitFusionModel().run(att16)
        emit(f"fig12_attn_{name}", ta.seconds * 1e6,
             f"vs_bitfusion:x{ta.speedup_over(bf):.2f} "
             f"vs_ant:x{ta.speedup_over(ant):.2f} "
             f"(paper: 3.97x / 1.54x)")
    emit("fig12_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
