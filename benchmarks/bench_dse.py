"""Fig. 9 — design space exploration on a 1024x1024 random 0-1 matrix:
(a) density vs TransRow width T; (b) ZR/TR/FR/PR pattern shares;
(c) density vs tile row number N at T=8; (d) node distance statistics.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core.patterns import tile_stats
from repro.core.scoreboard import dynamic_scoreboard


def _binary_matrix(seed=0, size=1024):
    return (np.random.default_rng(seed).random((size, size)) < 0.5)


def run():
    mat = _binary_matrix()
    t0 = time.perf_counter()

    # (a)+(b): vary T at tile row size 256
    for t in (2, 4, 8, 10):
        rows_per_tile = 256
        cols = (1024 // t) * t
        packed = np.packbits(mat[:, :cols].reshape(1024, cols // t, t),
                             axis=-1, bitorder="little")
        vals = packed[..., 0].astype(np.uint32) if t <= 8 else (
            packed[..., 0].astype(np.uint32)
            | (packed[..., 1].astype(np.uint32) << 8))
        flat = vals.T.reshape(-1)
        tiles = flat[: (len(flat) // rows_per_tile) * rows_per_tile]
        tiles = tiles.reshape(-1, rows_per_tile)[:64]
        st = tile_stats(dynamic_scoreboard(tiles, t))
        nz = st.pr + st.fr
        tot = np.maximum(nz + st.zr, 1)
        emit(f"fig9a_density_T{t}", 0.0,
             f"density={st.density.mean():.4f} bound={1.0/t:.4f}")
        emit(f"fig9b_patterns_T{t}", 0.0,
             f"zr={st.zr.mean():.1f} pr={st.pr.mean():.1f} "
             f"fr={st.fr.mean():.1f} tr={st.tr.mean():.1f}")

    # (c)+(d): vary N at T=8
    t = 8
    packed = np.packbits(mat.reshape(1024, 128, 8), axis=-1,
                         bitorder="little")[..., 0].astype(np.uint32)
    flat = packed.T.reshape(-1)
    for n in (16, 32, 64, 128, 256, 512, 1024):
        tiles = flat[: (len(flat) // n) * n].reshape(-1, n)
        tiles = tiles[:max(2, 16384 // n)]
        st = tile_stats(dynamic_scoreboard(tiles, t))
        dist = st.dist_hist.mean(0)
        emit(f"fig9c_density_N{n}", 0.0,
             f"density={st.density.mean():.4f}")
        emit(f"fig9d_dist_N{n}", 0.0,
             f"d1={dist[1]:.1f} d2={dist[2]:.2f} d3={dist[3]:.3f} "
             f"d4+={dist[4]:.3f}")
    emit("fig9_total", (time.perf_counter() - t0) * 1e6, "ok")


if __name__ == "__main__":
    run()
