"""End-to-end driver: train a ~135M-param model for a few hundred steps with
checkpointing + fault-tolerant restart, then resume and verify continuity.

By default uses a width-reduced smollm so it finishes on CPU; pass
--full for the real 135M config (slow on CPU, fine on a TPU slice).

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import logging

from repro.configs import get_config, get_reduced
from repro.distributed.fault import run_with_restarts
from repro.train.loop import train

logging.basicConfig(level=logging.INFO, format="%(levelname)s %(message)s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--inject-failure", action="store_true",
                    help="crash once mid-run to demo restart-from-checkpoint")
    args = ap.parse_args()

    cfg = get_config("smollm_135m") if args.full \
        else get_reduced("smollm_135m")
    seq, gb = (512, 32) if args.full else (64, 16)

    def loop(attempt):
        _, hist = train(cfg, seq_len=seq, global_batch=gb, steps=args.steps,
                        ckpt_dir=args.ckpt, ckpt_every=25, lr=3e-3,
                        metrics_path=f"{args.ckpt}/metrics.jsonl",
                        fail_at_step=args.steps // 2
                        if (args.inject_failure and attempt == 0) else None)
        return hist

    hist, restarts = run_with_restarts(loop, max_restarts=2)
    print(f"\nfirst loss {hist[0]['loss']:.3f} -> last {hist[-1]['loss']:.3f}"
          f" (restarts: {restarts})")


if __name__ == "__main__":
    main()
