"""Accuracy pipeline (paper Tbl. 3 stand-in, no LLaMA weights offline):
train a tiny LM, then evaluate perplexity under FP32, W8A8 and W4A8
TransitiveLinear serving — the paper's lossless-vs-quantizer separation:
transitive execution adds ZERO error on top of the quantizer.

Run: PYTHONPATH=src python examples/quantize_eval.py
"""
import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.models.model import Model
from repro.quant import QuantConfig, quantize_groupwise
from repro.train.loop import train

cfg = get_reduced("smollm_135m").replace(n_layers=2, dtype=jnp.float32)
state, hist = train(cfg, seq_len=64, global_batch=16, steps=60, lr=5e-3)
params = state["params"]
print(f"trained: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

data = SyntheticLM(cfg, 64, 16, seed=123)
batch = {k: v[0] for k, v in data.batch(999).items()}


def ppl(model, p):
    return math.exp(float(model.loss(p, batch)))


def quantize_params(params, w_bits, group=64):
    """PTQ: fp linear weights -> (qw, sg) leaves for mode='ptq' serving."""
    def q(tree):
        if isinstance(tree, dict) and "w" in tree and tree["w"].ndim >= 2:
            w = tree["w"]
            flat = w.reshape(-1, w.shape[-1])
            qw, sg = quantize_groupwise(flat, w_bits, min(group,
                                                          w.shape[-1]))
            return {"qw": qw.reshape(w.shape),
                    "sg": sg.reshape(w.shape[:-1] + (-1,))}
        if isinstance(tree, dict):
            return {k: q(v) for k, v in tree.items()}
        return tree
    return q(params)


m_fp = Model(cfg)
print(f"PPL fp32 : {ppl(m_fp, params):8.3f}")
for bits in (8, 4):
    qcfg = cfg.replace(quant=QuantConfig(mode="ptq", w_bits=bits, a_bits=8,
                                         group=64))
    qp = quantize_params(params, bits)
    qp = {**params, **{k: qp[k] for k in ("blocks",)}}
    m_q = Model(qcfg)
    p_int = ppl(m_q, qp)
    p_lut = math.exp(float(Model(qcfg.replace(
        quant=qcfg.quant.with_(backend="lut"))).loss(qp, batch)))
    print(f"PPL W{bits}A8 : {p_int:8.3f}   (transitive LUT path: {p_lut:8.3f}"
          f" — identical => lossless)")
