"""Serving example: quantize a model with the paper's technique (W4A8
TransitiveLinear + dynamic int8 attention), prefill a batch of prompts and
decode with greedy sampling — the Transitive-Array inference path.

Run: PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.launch.specs import serve_config
from repro.models.model import Model
from repro.train.serve_step import greedy_generate

# FP model + its W4A8 serving twin
cfg_fp = get_reduced("chatglm3_6b").replace(dtype=jnp.float32)
cfg_q = serve_config(cfg_fp)                      # ptq W4A8 + int8 attention

m_fp, m_q = Model(cfg_fp), Model(cfg_q)
params_fp = m_fp.init(jax.random.PRNGKey(0))
params_q = m_q.init(jax.random.PRNGKey(0))        # quantized at init

batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16),
                                      0, cfg_fp.vocab, jnp.int32)}
out_fp = greedy_generate(m_fp, params_fp, batch, max_len=64, n_steps=8)
out_q = greedy_generate(m_q, params_q, batch, max_len=64, n_steps=8)
print("fp  tokens:", np.asarray(out_fp))
print("q   tokens:", np.asarray(out_q))
print("note: weights differ (fp vs freshly-quantized init); the point is "
      "the full W4A8 transitive serving path runs end-to-end.")

# lossless check at the layer level: int paths agree bit-exactly
from repro.quant import QuantConfig, linear_init, linear_apply
cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=128)
p = linear_init(jax.random.PRNGKey(2), 256, 128, cfg)
x = jax.random.normal(jax.random.PRNGKey(3), (8, 256))
y_dot = linear_apply(p, x, cfg.with_(backend="int_dot"))
y_lut = linear_apply(p, x, cfg.with_(backend="lut"))
np.testing.assert_allclose(np.asarray(y_dot), np.asarray(y_lut), rtol=1e-5)
print("TransitiveLinear int-dot == LUT path ✓ (lossless, Sec. 2.1)")
