"""Quickstart: the paper's transitive sparsity in five minutes.

1. Bit-slice a quantized weight matrix into TransRows.
2. Build the dynamic Scoreboard (Hasse forest) and inspect its statistics.
3. Execute the GEMM through transitive reuse — bit-exact vs int matmul.
4. Run the same math through the Pallas TPU kernel (interpret mode on CPU).

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import bitslice, transitive
from repro.core.patterns import tile_stats
from repro.core.scoreboard import dynamic_scoreboard
from repro.kernels import ops

rng = np.random.default_rng(0)

# --- 1. quantized weights -> binary TransRows ------------------------------
W = rng.integers(-8, 8, size=(64, 64))            # int4 weights (N x K)
X = rng.integers(-128, 128, size=(64, 32))        # int8 activations (K x M)
rows = bitslice.transrow_matrix(W, bits=4, t=8)   # (S=4, N=64, K/T=8)
print(f"TransRows: {rows.shape} (S x N x K/T), values < 2^8")

# --- 2. the Scoreboard ------------------------------------------------------
tiles = rows.transpose(2, 0, 1).reshape(8, -1)    # one tile per k-chunk
st = tile_stats(dynamic_scoreboard(tiles, t=8))
print(f"density  : {st.density.mean():.3f}  (dense=1.0, paper bound 1/8)")
print(f"patterns : PR={st.pr.mean():.0f} FR={st.fr.mean():.0f} "
      f"TR={st.tr.mean():.0f} ZR={st.zr.mean():.0f} per tile")

# --- 3. lossless transitive GEMM -------------------------------------------
out = transitive.transitive_gemm(W, X, bits=4, t=8)
ref = W.astype(np.int64) @ X.astype(np.int64)
assert (out == ref).all()
print("transitive GEMM == int GEMM: bit-exact ✓")

# --- 4. the TPU kernel (split-LUT doubling, interpret mode) ----------------
qx = jnp.asarray(X.T, jnp.int8)                   # (M, K) activations
qw = jnp.asarray(W, jnp.int8)
out_k = np.asarray(ops.transitive_gemm(qx, qw, w_bits=4, t=8))
assert (out_k == ref.T).all()
print("Pallas transitive kernel: bit-exact ✓")
