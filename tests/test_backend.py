"""Backend registry tests (core/backend.py + the rewired dispatch sites).

Covers the ISSUE-4 acceptance surface: registry semantics (duplicate
registration, unknown-name errors listing the valid names), the
``EngineConfig`` dataclass, the legacy ``QuantConfig(path=...)``
deprecation shim (warns AND stays bit-exact), custom-backend registration
flowing through ``linear_apply`` untouched, per-backend PlanCache counter
attribution, the ``compile(..., mesh, specs)`` sharding hook, and the
backend-tagged DevicePlan persistence bundle.
"""
import warnings

import numpy as np
import pytest

import repro.core.backend as BK
from repro.core.backend import (EngineConfig, TransitiveBackend,
                                get_backend, list_backends,
                                register_backend, shard_device_plan,
                                unregister_backend)
from repro.core.engine import (DEVICE_DATA_FIELDS, BatchedTransitiveEngine,
                               ExecutionPlan)

BUILTINS = ("int_dot", "lut", "pallas", "engine", "engine_jit",
            "engine_pallas")


@pytest.fixture
def cache():
    """Fresh process-default plan cache per test; restores the previous."""
    from repro.core.plancache import PlanCache, set_default_cache
    c = PlanCache(capacity=64)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


# -- registry ---------------------------------------------------------------

def test_all_builtin_backends_registered():
    assert set(BUILTINS) <= set(list_backends())


def test_capability_flags_declared():
    """The four strategies declare the capabilities the launchers key on."""
    assert not get_backend("int_dot").needs_plan
    assert get_backend("engine").needs_plan
    assert not get_backend("engine").device_resident
    for name in ("engine_jit", "engine_pallas"):
        b = get_backend(name)
        assert b.needs_plan and b.device_resident
    for name in BUILTINS:           # everything here runs on the CPU runner
        assert get_backend(name).cpu_ok
        assert get_backend(name).supports_groups


def test_duplicate_registration_is_loud():
    with pytest.raises(ValueError, match="already registered"):
        register_backend(BK.IntDotBackend())
    # replace=True is the explicit override
    prev = get_backend("int_dot")
    try:
        mine = register_backend(BK.IntDotBackend(), replace=True)
        assert get_backend("int_dot") is mine
    finally:
        register_backend(prev, replace=True)


def test_unknown_backend_error_lists_valid_names():
    with pytest.raises(KeyError) as ei:
        get_backend("definitely_not_a_backend")
    msg = str(ei.value)
    for name in BUILTINS:
        assert name in msg
    with pytest.raises(KeyError):
        unregister_backend("definitely_not_a_backend")


def test_nameless_backend_rejected():
    with pytest.raises(ValueError, match="name"):
        register_backend(TransitiveBackend())


def test_get_backend_accepts_instances_and_configs():
    from repro.quant import QuantConfig
    b = get_backend("engine_jit")
    assert get_backend(b) is b
    assert get_backend(QuantConfig(backend="engine_jit")) is b


def test_custom_backend_flows_through_linear_apply():
    """A registered custom backend is selectable by name with no dispatch
    changes anywhere — the point of the registry."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply

    class ShiftyIntDot(BK.IntDotBackend):
        name = "custom_int_dot"

    register_backend(ShiftyIntDot())
    try:
        cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64,
                          backend="custom_int_dot")
        p = linear_init(jax.random.PRNGKey(0), 128, 16, cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 128), jnp.float32)
        np.testing.assert_array_equal(
            np.asarray(linear_apply(p, x, cfg)),
            np.asarray(linear_apply(p, x, cfg.with_(backend="int_dot"))))
    finally:
        unregister_backend("custom_int_dot")
    with pytest.raises(KeyError):
        get_backend("custom_int_dot")


# -- EngineConfig -----------------------------------------------------------

def test_engine_config_from_quant():
    from repro.quant import QuantConfig
    q = QuantConfig(mode="ptq", w_bits=4, transrow_t=4)
    e = EngineConfig.from_quant(q, groups=3)
    assert (e.w_bits, e.t, e.groups) == (4, 4, 3)
    assert e.key() == (4, 4, 3)


def test_plancache_accepts_config_and_legacy_ints(rng):
    from repro.core.plancache import PlanCache
    c = PlanCache()
    w = rng.integers(-8, 8, (5, 32))
    p1 = c.get_or_build(w, EngineConfig(w_bits=4, t=8))
    p2 = c.get_or_build(w, 4, 8)          # legacy ints -> same entry
    assert p1 is p2
    assert c.stats()["misses"] == 1 and c.stats()["hits"] == 1
    with pytest.raises(TypeError):        # both forms at once is an error
        c.get_or_build(w, EngineConfig(4, 8), 8)
    with pytest.raises(TypeError):        # ... including a stray groups=
        c.get_or_build(w, EngineConfig(4, 8), groups=16)
    with pytest.raises(TypeError):        # legacy form without t
        c.get_or_build(w, 4)


def test_device_memo_keyed_per_compile_hook(rng):
    """A custom device backend with its own lowering must not be served
    another backend's memoised DevicePlan — while backends sharing one
    compile hook (engine_jit / engine_pallas) share one memoised pytree
    instead of double-compiling."""
    import jax
    from repro.core.plancache import PlanCache

    class Doubler(BK.EngineJitBackend):
        name = "custom_doubler"

        def compile(self, plan, mesh=None, specs=None):
            d = super().compile(plan, mesh=mesh, specs=specs)
            # a deliberately different (useless) lowering layout
            return jax.tree.map(lambda a: a, d), "tagged"

    register_backend(Doubler())
    try:
        c = PlanCache()
        w = rng.integers(-8, 8, (5, 32))
        ecfg = EngineConfig(w_bits=4, t=8)
        d_jit = c.get_or_build_device(w, ecfg, backend="engine_jit")
        d_custom = c.get_or_build_device(w, ecfg, backend="custom_doubler")
        assert isinstance(d_custom, tuple) and d_custom[1] == "tagged"
        assert c.get_or_build_device(w, ecfg,
                                     backend="engine_jit") is d_jit
        assert c.get_or_build_device(w, ecfg,
                                     backend="custom_doubler") is d_custom
        # shared hook -> shared lowering, no duplicate compile
        assert c.get_or_build_device(w, ecfg,
                                     backend="engine_pallas") is d_jit
    finally:
        unregister_backend("custom_doubler")


def test_engine_backend_uses_passed_plan_without_cache_traffic(rng):
    """The protocol's plan argument is honored: an engine execute with a
    resolved plan makes zero lookups in the process cache."""
    import jax.numpy as jnp
    from repro.core.plancache import PlanCache, set_default_cache
    b = get_backend("engine")
    w = rng.integers(-8, 8, (6, 32))
    x = rng.integers(-128, 128, (3, 32))
    ecfg = EngineConfig(w_bits=4, t=8)
    plan = BatchedTransitiveEngine(4, 8).plan(w)
    empty = PlanCache()
    prev = set_default_cache(empty)
    try:
        got = np.asarray(b.execute(jnp.asarray(x, jnp.int8),
                                   jnp.asarray(w, jnp.int8),
                                   plan, None, ecfg))
    finally:
        set_default_cache(prev)
    np.testing.assert_array_equal(got,
                                  x.astype(np.int64) @ w.astype(np.int64).T)
    s = empty.stats()
    assert s["hits"] == 0 and s["misses"] == 0
    # a plan whose signature disagrees with the config is a loud error
    with pytest.raises(ValueError, match="signature"):
        b.execute(jnp.asarray(x, jnp.int8), jnp.asarray(w, jnp.int8),
                  plan, None, EngineConfig(w_bits=8, t=8))


# -- the legacy path= shim --------------------------------------------------

def test_path_shim_warns_and_resolves():
    from repro.quant import QuantConfig
    cfg = QuantConfig(mode="ptq", path="engine")
    with pytest.warns(DeprecationWarning, match="deprecated"):
        assert cfg.backend_name() == "engine"
    # without path, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert QuantConfig(backend="lut").backend_name() == "lut"


@pytest.mark.parametrize("legacy", ["int_dot", "lut", "engine"])
def test_path_shim_bit_exact_with_backend_field(legacy):
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=0)
    p = linear_init(jax.random.PRNGKey(0), 64, 12, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64), jnp.float32)
    with pytest.warns(DeprecationWarning):
        y_legacy = linear_apply(p, x, cfg.with_(path=legacy))
    y_new = linear_apply(p, x, cfg.with_(backend=legacy))
    np.testing.assert_array_equal(np.asarray(y_legacy), np.asarray(y_new))


def test_serve_config_path_kwarg_shim():
    from repro.configs import get_reduced
    from repro.launch.specs import serve_config
    with pytest.warns(DeprecationWarning):
        cfg = serve_config(get_reduced("smollm-135m"), w_bits=4,
                           path="engine")
    assert cfg.quant.backend_name() == "engine"


# -- per-backend cache counters ---------------------------------------------

def test_plancache_counters_have_backend_dimension(rng):
    from repro.core.plancache import PlanCache
    c = PlanCache()
    w = rng.integers(-8, 8, (5, 32))
    ecfg = EngineConfig(w_bits=4, t=8)
    c.get_or_build(w, ecfg, backend="engine")          # miss
    c.get_or_build(w, ecfg, backend="engine")          # hit
    c.get_or_build(w, ecfg, backend="engine_jit")      # hit, other backend
    c.get_or_build(w, ecfg)                            # untagged hit
    s = c.stats()
    assert s["misses"] == 1 and s["hits"] == 3
    assert s["backends"]["engine"] == {"hits": 1, "misses": 1}
    assert s["backends"]["engine_jit"] == {"hits": 1, "misses": 0}
    c.reset_stats()
    assert c.stats()["backends"] == {}


# -- sharding hook: compile(..., mesh, specs) -------------------------------

def _mesh():
    import jax
    return jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))


def test_compile_with_partition_specs_bit_exact(rng):
    """The acceptance smoke: a DevicePlan compiled with explicit
    PartitionSpecs has bit-identical leaves and executes bit-exactly."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.engine import run_device_jit
    w = rng.integers(-8, 8, (6, 32))
    plan = BatchedTransitiveEngine(4, 8).plan(w)
    b = get_backend("engine_jit")
    plain = b.compile(plan)
    sharded = b.compile(plan, mesh=_mesh(), specs=P())
    for f in DEVICE_DATA_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(sharded, f)),
                                      np.asarray(getattr(plain, f)))
    x = rng.integers(-128, 128, (32, 4))
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(sharded, jnp.asarray(x))),
        w.astype(np.int64) @ x.astype(np.int64))


def test_shard_device_plan_spec_forms(rng):
    from jax.sharding import PartitionSpec as P
    plan = BatchedTransitiveEngine(4, 8).plan(rng.integers(-8, 8, (4, 16)))
    dplan = get_backend("engine_jit").compile(plan)
    mesh = _mesh()
    for specs in (None, P(), {"gather_idx": P()}):
        out = shard_device_plan(dplan, mesh, specs)
        np.testing.assert_array_equal(np.asarray(out.gather_idx),
                                      np.asarray(dplan.gather_idx))
    with pytest.raises(ValueError, match="unknown DevicePlan leaf"):
        shard_device_plan(dplan, mesh, {"nonsense": P()})
    with pytest.raises(TypeError):
        shard_device_plan(dplan, mesh, 42)


def test_attach_device_plans_threads_mesh_and_specs(cache):
    """attach_device_plans(mesh=, specs=) places stacked plan leaves; the
    values (and the serving output) are unchanged."""
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.core.plancache import attach_device_plans
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64,
                      backend="engine_jit")
    stacked = jax.vmap(lambda k: linear_init(k, 128, 16, cfg))(
        jax.random.split(jax.random.PRNGKey(1), 3))
    plain = attach_device_plans({"b": stacked}, cfg, cache=cache)
    placed = attach_device_plans({"b": stacked}, cfg, cache=cache,
                                 mesh=_mesh(), specs=P("data"))
    for f in DEVICE_DATA_FIELDS:
        np.testing.assert_array_equal(
            np.asarray(getattr(placed["b"]["dplan"], f)),
            np.asarray(getattr(plain["b"]["dplan"], f)))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 128))
    p0 = jax.tree.map(lambda a: a[0], placed["b"])
    np.testing.assert_array_equal(
        np.asarray(linear_apply(p0, x, cfg)),
        np.asarray(linear_apply(jax.tree.map(lambda a: a[0], stacked), x,
                                cfg.with_(backend="int_dot"))))


def test_attach_device_plans_rejects_planless_backend(cache):
    from repro.core.plancache import attach_device_plans
    from repro.quant import QuantConfig
    with pytest.raises(ValueError, match="device plans"):
        attach_device_plans({}, QuantConfig(mode="ptq", backend="int_dot"),
                            cache=cache)


# -- backend-tagged DevicePlan persistence ----------------------------------

def test_device_plan_persistence_bundle(tmp_path, rng):
    """ExecutionPlan.save(device=, backend=) round-trips the cached
    lowering across processes: every leaf bit-exact, backend tag intact,
    and the loaded device plan executes bit-exactly — including one
    compiled with explicit PartitionSpecs."""
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core.engine import run_device_jit
    w = rng.integers(-8, 8, (5, 32))
    eng = BatchedTransitiveEngine(4, 8)
    plan = eng.plan(w, groups=2)
    b = get_backend("engine_jit")
    dplan = b.compile(plan, mesh=_mesh(), specs=P())
    path = tmp_path / "bundle.npz"
    plan.save(path, device=dplan, backend=b.name)
    bundle = ExecutionPlan.load_bundle(path)
    assert bundle.backend == "engine_jit"
    for f in DEVICE_DATA_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(bundle.device, f)),
                                      np.asarray(getattr(dplan, f)))
    assert (bundle.device.t, bundle.device.bits, bundle.device.n,
            bundle.device.k, bundle.device.groups) == \
        (dplan.t, dplan.bits, dplan.n, dplan.k, dplan.groups)
    x = rng.integers(-128, 128, (32, 3))
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(bundle.device, jnp.asarray(x))),
        np.asarray(run_device_jit(dplan, jnp.asarray(x))))
    # the host plan in the bundle still round-trips like a plain save
    np.testing.assert_array_equal(eng.run(bundle.plan, x), eng.run(plan, x))


def test_plan_save_without_device_loads_none(tmp_path, rng):
    plan = BatchedTransitiveEngine(4, 8).plan(rng.integers(-8, 8, (4, 16)))
    path = tmp_path / "plain.npz"
    plan.save(path)
    bundle = ExecutionPlan.load_bundle(path)
    assert bundle.device is None and bundle.backend is None
    np.testing.assert_array_equal(bundle.plan.rows, plan.rows)


# -- CLI helper (the CI serve-smoke loop consumes this) ---------------------

def test_backend_module_cli_lists_cpu_backends():
    import subprocess
    import sys
    import os
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.core.backend", "--cpu"],
        capture_output=True, text=True, env=env, check=True).stdout.split()
    assert set(BUILTINS) <= set(out)
