"""THE paper claim: transitive execution is lossless (bit-exact vs int GEMM).

Property-tested across bit widths, TransRow widths, shapes and data
distributions — including adversarial all-ones/all-zeros/duplicate-heavy
matrices.
"""
import numpy as np
from _compat import given, settings, strategies as st

from repro.core import transitive


@given(bits=st.sampled_from([2, 4, 8]), t=st.sampled_from([4, 8]),
       n=st.integers(1, 20), kt=st.integers(1, 5), m=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_lossless_random(bits, t, n, kt, m, seed):
    rng = np.random.default_rng(seed)
    k = kt * t
    w = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=(n, k))
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    got = transitive.transitive_gemm(w, x, bits, t)
    np.testing.assert_array_equal(got, want)


@given(fill=st.sampled_from([-8, -1, 0, 1, 7]), seed=st.integers(0, 99))
@settings(max_examples=15, deadline=None)
def test_lossless_degenerate(fill, seed):
    rng = np.random.default_rng(seed)
    w = np.full((7, 16), fill)
    x = rng.integers(-128, 128, size=(16, 3))
    want = w.astype(np.int64) @ x.astype(np.int64)
    np.testing.assert_array_equal(
        transitive.transitive_gemm(w, x, 4, 8), want)


def test_lossless_duplicate_heavy(rng):
    """FR-dominated tiles (few unique patterns) stay exact."""
    pats = rng.integers(-8, 8, size=(3, 16))
    w = pats[rng.integers(0, 3, size=64)]
    x = rng.integers(-128, 128, size=(16, 5))
    want = w.astype(np.int64) @ x.astype(np.int64)
    got, totals = transitive.transitive_gemm_stats(w, x, 4, 8)
    np.testing.assert_array_equal(got, want)
    assert totals["density"] < 0.30      # heavy reuse visible in ops


def test_stats_density_sane(rng):
    w = rng.integers(-128, 128, size=(64, 64))
    x = rng.integers(-128, 128, size=(64, 4))
    got, totals = transitive.transitive_gemm_stats(w, x, 8, 8)
    np.testing.assert_array_equal(got, w.astype(np.int64) @ x.astype(np.int64))
    assert 1 / 8 - 0.02 <= totals["density"] <= 0.75
    assert totals["bit_ops"] <= totals["dense_ops"]
