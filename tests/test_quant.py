"""Quantization substrate + TransitiveLinear path equivalence."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _compat import given, settings, strategies as st

import repro.quant.quantize as Q
from repro.quant import QuantConfig, linear_init, linear_apply


@given(bits=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_groupwise_roundtrip_error(bits, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
    q, s = Q.quantize_groupwise(w, bits, 128)
    back = Q.dequantize_groupwise(q, s, 128)
    # max error bounded by half an LSB per group
    lsb = np.asarray(s).repeat(128, -1) * 1.0
    err = np.abs(np.asarray(back - w))
    assert (err <= 0.5 * lsb + 1e-6).all()


def test_per_token_scale_shape():
    x = jnp.ones((2, 3, 64))
    q, s = Q.quantize_per_token(x)
    assert q.shape == x.shape and s.shape == (2, 3, 1)
    assert q.dtype == jnp.int8


@pytest.mark.parametrize("group", [64, 128, 0])
@pytest.mark.parametrize("w_bits", [4, 8])
def test_linear_paths_agree(group, w_bits):
    cfg = QuantConfig(mode="ptq", w_bits=w_bits, a_bits=8, group=group)
    p = linear_init(jax.random.PRNGKey(0), 256, 96, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 7, 256), jnp.float32)
    y_int = linear_apply(p, x, cfg.with_(backend="int_dot"))
    y_lut = linear_apply(p, x, cfg.with_(backend="lut"))
    y_pal = linear_apply(p, x, cfg.with_(backend="pallas"))
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_lut),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_int), np.asarray(y_pal),
                               rtol=1e-4, atol=1e-4)


def test_ptq_close_to_fp():
    cfg_fp = QuantConfig(mode="none")
    cfg_q = QuantConfig(mode="ptq", w_bits=8, a_bits=8, group=128)
    key = jax.random.PRNGKey(0)
    p_fp = linear_init(key, 256, 128, cfg_fp, dtype=jnp.float32)
    p_q = linear_init(key, 256, 128, cfg_q)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256), jnp.float32)
    y_fp = np.asarray(linear_apply(p_fp, x, cfg_fp))
    y_q = np.asarray(linear_apply(p_q, x, cfg_q))
    rel = np.abs(y_q - y_fp).mean() / (np.abs(y_fp).mean() + 1e-9)
    assert rel < 0.02, rel           # W8A8 is near-lossless


def test_qat_ste_grads():
    cfg = QuantConfig(mode="qat", w_bits=4, group=64)
    p = linear_init(jax.random.PRNGKey(0), 64, 32, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64), jnp.float32)
    g = jax.grad(lambda pp: (linear_apply(pp, x, cfg) ** 2).mean())(p)
    gw = np.asarray(g["w"])
    assert np.isfinite(gw).all() and np.abs(gw).sum() > 0


def test_grouped_ptq_shape_mismatch_is_loud():
    """A PTQ layer whose d_in is not divisible by its scale-group count
    must raise, not floor-divide into wrong groups and silently mis-scale
    every output channel (e.g. a weight sliced after quantization)."""
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64)
    p = linear_init(jax.random.PRNGKey(0), 192, 16, cfg)   # sg: (16, 3)
    bad = {"qw": p["qw"][:, :100], "sg": p["sg"]}          # 100 % 3 != 0
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 100), jnp.float32)
    with pytest.raises(ValueError, match=r"\(16, 100\).*3 scale groups"):
        linear_apply(bad, x, cfg)
    # divisible slices still pass the guard (3 groups of 32)
    ok = {"qw": p["qw"][:, :96], "sg": p["sg"]}
    y = linear_apply(ok, x[:, :96], cfg)
    assert np.isfinite(np.asarray(y)).all()
