"""Scoreboard invariants (paper Sec. 3, Fig. 5) — property-based."""
import numpy as np
from _compat import given, settings, strategies as st

from repro.core import hasse
from repro.core.patterns import tile_stats
from repro.core.scoreboard import (dynamic_scoreboard, static_scoreboard,
                                   static_tile_stats)


def _rows(seed, tiles=4, n=64, t=8):
    return np.random.default_rng(seed).integers(
        0, 1 << t, size=(tiles, n)).astype(np.uint32)


@given(seed=st.integers(0, 2**31 - 1), t=st.sampled_from([4, 8]))
@settings(max_examples=25, deadline=None)
def test_prefix_is_subset_distance1(seed, t):
    """Every executed non-outlier node's selected prefix is a covering
    (one-bit-cleared) subset — the forest edges are Hasse edges."""
    rows = _rows(seed, t=t, n=48)
    si = dynamic_scoreboard(rows, t)
    exe = si.executed
    for ti in range(si.tiles):
        for node in np.nonzero(exe[ti])[0]:
            pre = si.prefix[ti, node]
            assert pre >= 0, (ti, node)
            assert hasse.is_prefix(pre, node)
            assert hasse.popcount(np.uint64(node ^ pre)) == 1

    # lanes: every executed node carries the lane of its prefix
    for ti in range(si.tiles):
        for node in np.nonzero(exe[ti])[0]:
            pre = si.prefix[ti, node]
            if pre > 0:
                assert si.lane[ti, node] == si.lane[ti, pre]
            else:
                assert si.lane[ti, node] == int(np.log2(node))


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_all_present_nodes_executable(seed):
    """Every present TransRow value is either executed or an outlier."""
    rows = _rows(seed)
    si = dynamic_scoreboard(rows, 8)
    covered = si.executed | si.outlier
    for ti in range(si.tiles):
        present = np.unique(rows[ti])
        present = present[present != 0]
        assert covered[ti, present].all()


def test_paper_fig1_example():
    """Fig. 1: rows {1011,1111,0011,0010} need 4 ops vs 10 bit-sparse."""
    si = dynamic_scoreboard(
        np.array([[0b1011, 0b1111, 0b0011, 0b0010]]), 4)
    st_ = tile_stats(si)
    assert st_.ppe_ops[0] == 4
    assert st_.bit_ops[0] == 10
    assert st_.tr[0] == 0


def test_density_bounds_random_t8():
    """Sec. 5.2: runtime density ~1/T at N=256; PPE density below it;
    bit density ~0.5; distances: none >= 4 at N=256."""
    rows = _rows(1, tiles=32, n=256)
    st_ = tile_stats(dynamic_scoreboard(rows, 8))
    d = st_.density.mean()
    assert 0.118 < d < 0.135, d
    assert (st_.density_ppe < st_.density + 1e-9).all()
    assert abs(st_.bit_density.mean() - 0.5) < 0.02
    assert st_.dist_hist[:, 4].sum() == 0


def test_expected_unique_nodes():
    """Sec. 5.9: E[#unique] of 256 uniform 8-bit TransRows ~= 162."""
    rows = _rows(2, tiles=64, n=256)
    si = dynamic_scoreboard(rows, 8)
    mean_unique = si.present.sum(-1).mean()
    assert abs(mean_unique - 162) < 3, mean_unique


def test_zero_rows_skipped():
    si = dynamic_scoreboard(np.zeros((1, 16), np.uint32), 8)
    st_ = tile_stats(si)
    assert st_.ppe_ops[0] == 0 and st_.ape_ops[0] == 0
    assert st_.zr[0] == 16


def test_static_vs_dynamic_density_crossover():
    """Fig. 13: static SI matches dynamic at large tile rows, degrades at
    small tile rows (SI misses)."""
    rng = np.random.default_rng(3)
    all_rows = rng.integers(0, 256, size=(1 << 14,)).astype(np.uint32)
    ssi = static_scoreboard(all_rows, 8)

    def density(tile_rows):
        tiles = all_rows.reshape(-1, tile_rows)[:16]
        s = static_tile_stats(ssi, tiles)
        return (np.maximum(s["ppe"], s["ape"]) / s["dense"]).mean()

    d64, d1024 = density(64), density(1024)
    dyn64 = tile_stats(dynamic_scoreboard(
        all_rows.reshape(-1, 64)[:16], 8)).density.mean()
    assert d64 > dyn64          # SI misses hurt small tiles
    assert d1024 < d64 * 0.75   # and wash out at large tiles


def test_load_balance():
    """Balanced forest: max-lane PPE load within 3x of mean (T=8, N=256)."""
    rows = _rows(4, tiles=16, n=256)
    si = dynamic_scoreboard(rows, 8)
    tot = si.wl_ppe.sum(-1)
    mx = si.wl_ppe.max(-1)
    assert (mx <= np.ceil(tot / 8 * 3)).all(), (mx, tot / 8)
