"""End-to-end system behaviour: training converges, checkpoints resume
exactly, fault injection recovers, serving generates."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.distributed.fault import run_with_restarts
from repro.models.model import Model
from repro.train.loop import train
from repro.train.serve_step import greedy_generate


@pytest.fixture(scope="module")
def tiny_cfg():
    return get_reduced("smollm_135m").replace(n_layers=2)


@pytest.mark.slow
def test_training_learns(tiny_cfg):
    """Loss on structured synthetic data must drop measurably."""
    state, hist = train(tiny_cfg, seq_len=64, global_batch=16, steps=30,
                        lr=5e-3, ckpt_dir=None)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.5, (first, last)


@pytest.mark.slow
def test_checkpoint_resume_exact(tiny_cfg, tmp_path):
    """Same final loss whether run straight or crashed+resumed (restore is
    bit-exact and the data pipeline is step-keyed, so the tails match)."""
    _, h1 = train(tiny_cfg, seq_len=32, global_batch=8, steps=12,
                  ckpt_dir=None, lr=1e-3)

    d = str(tmp_path / "ck")
    with pytest.raises(RuntimeError, match="injected"):
        train(tiny_cfg, seq_len=32, global_batch=8, steps=12,
              ckpt_dir=d, ckpt_every=3, lr=1e-3, fail_at_step=7)
    _, h2b = train(tiny_cfg, seq_len=32, global_batch=8, steps=12,
                   ckpt_dir=d, ckpt_every=3, lr=1e-3)
    np.testing.assert_allclose(h1[-1]["loss"], h2b[-1]["loss"],
                               rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_fault_injection_recovers(tiny_cfg, tmp_path):
    d = str(tmp_path / "ck")
    calls = {"n": 0}

    def loop(attempt):
        calls["n"] += 1
        _, hist = train(tiny_cfg, seq_len=32, global_batch=8, steps=10,
                        ckpt_dir=d, ckpt_every=2, lr=1e-3,
                        fail_at_step=5 if attempt == 0 else None)
        return hist[-1]["step"]

    final, restarts = run_with_restarts(loop, max_restarts=2)
    assert final == 9 and restarts == 1 and calls["n"] == 2


@pytest.mark.slow
def test_generation_roundtrip(tiny_cfg):
    model = Model(tiny_cfg.replace(dtype=jnp.float32))
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    # n_steps is the number of generated tokens (the explicit PR-5
    # contract: prefill argmax + n_steps-1 decode steps; 0 = none)
    toks = greedy_generate(model, params, batch, max_len=32, n_steps=5)
    assert toks.shape == (2, 5)
    assert (np.asarray(toks) >= 0).all()
    assert (np.asarray(toks) < tiny_cfg.vocab).all()
    assert greedy_generate(model, params, batch, max_len=32,
                           n_steps=0).shape == (2, 0)
