"""Plan-IR verifier + static cost certifier (ISSUE 10).

Three layers of evidence:

* a **mutation corpus**: ~10 seeded corruptions of a valid plan — cycle
  spliced into the reuse graph, OOB gather index, reordered level,
  non-dead pad lane, truncated bundle npz, ... — each caught with
  exactly ONE error finding whose path names the corrupted field;
* **gate attribution**: each corruption class is refused at the right
  trust boundary (PlanCache publish / bundle load *before* the sha256
  check / swap staging);
* **budgets**: the live-page decode and swap-trace-count budgets pass
  on the healthy paths and demonstrably fail when hand-broken.
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import pytest

import jax

from repro.analysis import costcheck, planlint
from repro.analysis.planlint import (PlanVerificationError,
                                     list_plan_rules, verify_bundle_file,
                                     verify_device_plan, verify_manifest,
                                     verify_plan)
from repro.core.backend import EngineConfig, get_backend
from repro.core.engine import (BatchedTransitiveEngine, LevelStep,
                               pad_device_plan)
from repro.core.plancache import (PlanCache, set_default_cache,
                                  weight_fingerprint)


@pytest.fixture(scope="module")
def plan():
    w = np.asarray(jax.random.randint(
        jax.random.PRNGKey(0), (8, 16), -8, 8))
    return BatchedTransitiveEngine(bits=4, t=4).plan(w)


@pytest.fixture(scope="module")
def dev(plan):
    return get_backend("engine_jit").compile(plan)


@pytest.fixture()
def cache():
    c = PlanCache(capacity=32)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


def _one(findings, rule, field_sub):
    """The corpus contract: exactly one error finding, right rule, and
    a path that names the corrupted field."""
    assert len(findings) == 1, [f.format() for f in findings]
    f = findings[0]
    assert f.severity == "error", f.format()
    assert f.rule == rule, f.format()
    assert field_sub in f.path, f.format()
    return f


def _mut_step(plan, i, **arrays):
    """Replace selected arrays of ``plan.steps[i]``."""
    s = plan.steps[i]
    new = LevelStep(**{k: arrays.get(k, getattr(s, k))
                       for k in ("tile", "node", "prefix", "bit")})
    steps = plan.steps[:i] + (new,) + plan.steps[i + 1:]
    return dataclasses.replace(plan, steps=steps)


def _np(x):
    return np.array(x, dtype=np.int64)


# -- the healthy artifacts verify clean --------------------------------------

def test_clean_plan_and_device(plan, dev):
    assert verify_plan(plan) == []
    assert verify_device_plan(dev, plan) == []


def test_clean_padded_and_stacked(plan, dev):
    from repro.core.engine import compile_plans
    d = int(np.asarray(dev.direct_idx).shape[-1])
    assert verify_device_plan(pad_device_plan(dev, d + 3), plan) == []
    assert verify_device_plan(compile_plans([plan, plan])) == []


# -- mutation corpus: plan IR ------------------------------------------------

def test_mut_cycle_spliced_into_reuse_graph(plan):
    """A level-1 edge whose prefix is a LATER-level node: still a
    covering single-bit edge (so the shallow rules pass), but the
    schedule is no longer a DAG in execution order."""
    s = plan.steps[0]
    nd = int(s.node[0])
    b = next(bb for bb in range(plan.t) if not (nd >> bb) & 1)
    prefix = _np(s.prefix); prefix[0] = nd | (1 << b)
    bit = _np(s.bit); bit[0] = b
    bad = _mut_step(plan, 0, prefix=prefix, bit=bit)
    f = _one(verify_plan(bad), "plan-schedule-dag", "steps[0].prefix[0]")
    assert "not produced at any earlier level" in f.message


def test_mut_reordered_level(plan):
    """Swapping two levels executes level-2 nodes in the level-1 slot."""
    swapped = dataclasses.replace(
        plan, steps=(plan.steps[1], plan.steps[0]) + plan.steps[2:])
    _one(verify_plan(swapped), "plan-schedule-levels", "steps[0].node")


def test_mut_duplicate_production(plan):
    s = plan.steps[1]
    arrays = {k: _np(getattr(s, k))
              for k in ("tile", "node", "prefix", "bit")}
    for a in arrays.values():      # edge 1 := copy of edge 0
        a[1] = a[0]
    bad = _mut_step(plan, 1, **arrays)
    _one(verify_plan(bad), "plan-schedule-dag", "steps[1].node[1]")


def test_mut_oob_step_node(plan):
    node = _np(plan.steps[0].node)
    node[0] = 1 << plan.t                  # one past the tile table
    bad = _mut_step(plan, 0, node=node)
    _one(verify_plan(bad), "plan-bounds", "node")


def test_mut_oob_rows(plan):
    rows = _np(plan.rows)
    rows[0, 0, 0] = 1 << plan.t
    bad = dataclasses.replace(plan, rows=rows)
    _one(verify_plan(bad), "plan-bounds", "rows[0, 0, 0]")


def test_mut_groups_mismatch(plan):
    bad = dataclasses.replace(plan, groups=3)   # J=4 tiles: 3 ∤ 4
    _one(verify_plan(bad), "plan-shape", "groups")


# -- mutation corpus: device plan --------------------------------------------

def test_mut_oob_gather_index(plan, dev):
    gi = _np(dev.gather_idx)
    r = plan.n_tiles << plan.t
    gi[0, 0, 0] = r                        # one past the psum table
    bad = dataclasses.replace(dev, gather_idx=gi)
    f = _one(verify_device_plan(bad, plan), "device-bounds",
             "gather_idx[0, 0, 0]")
    assert str(r) in f.message


def test_mut_identity_lane_reads_real_row(plan, dev):
    ls, lx = _np(dev.level_src), _np(dev.level_xsrc)
    r = np.arange(ls.shape[-1])
    lv, row = np.argwhere(ls == r[None, :])[0]   # an identity lane
    lx[lv, row] = 0                       # now adds a real activation
    bad = dataclasses.replace(dev, level_xsrc=lx)
    _one(verify_device_plan(bad, plan), "device-identity-lanes",
         f"level_xsrc[{lv}, {row}]")


def test_mut_level_monotonicity_broken(plan, dev):
    """A level-1 lane gathering a row that is itself executed at level
    2 reads an unsettled psum — the device-side cycle."""
    ls = _np(dev.level_src)
    r = np.arange(ls.shape[-1])
    lvl1 = np.flatnonzero(ls[0] != r)     # rows executed at level 1
    lvl2 = np.flatnonzero(ls[1] != r)     # rows executed at level 2
    assert lvl1.size and lvl2.size
    ls[0, lvl1[0]] = lvl2[0]
    bad = dataclasses.replace(dev, level_src=ls)
    _one(verify_device_plan(bad, plan), "device-level-monotone",
         f"level_src[0, {lvl1[0]}]")


def test_mut_non_dead_pad_lane(plan, dev):
    d = int(np.asarray(dev.direct_idx).shape[-1])
    padded = pad_device_plan(dev, d + 2)
    db = _np(padded.direct_bits)
    db[-1, 0] = 1                         # pad lane with a live bit
    bad = dataclasses.replace(padded, direct_bits=db)
    f = _one(verify_device_plan(bad, plan), "device-direct-dispatch",
             f"direct_bits[{d + 1}, 0]")
    assert "pad lane" in f.message


def test_mut_content_corruption_caught_by_agreement(plan, dev):
    """A flipped source that stays individually well-formed is still
    caught: the lowering no longer agrees with its plan."""
    ls = _np(dev.level_src)
    r = np.arange(ls.shape[-1])
    never_exec = np.flatnonzero((ls == r[None, :]).all(0))
    direct = set(_np(dev.direct_idx).tolist())
    gathered = set(ls[ls != r[None, :]].tolist())
    lanes = [int(rr) for rr in never_exec
             if rr not in direct and rr not in gathered]
    srcs = [int(rr) for rr in never_exec
            if rr not in direct and rr != lanes[0]]
    lane, src = lanes[0], srcs[0]
    lv = ls.shape[0] - 1
    # a last-level lane gathering a never-executed row: in bounds,
    # identity-consistent, monotone (src settles "at level -1"), one
    # writer — only the recompile comparison can see it
    ls[lv, lane] = src
    lx = _np(dev.level_xsrc)
    lx[lv, lane] = 0                      # live lane: xsrc != K
    bad = dataclasses.replace(dev, level_src=ls, level_xsrc=lx)
    _one(verify_device_plan(bad, plan), "plan-device-agreement",
         "level_src")


# -- mutation corpus: persisted bundles --------------------------------------

def test_mut_truncated_bundle_npz(tmp_path, plan, dev):
    p = str(tmp_path / "layer0.npz")
    plan.save(p, device=dev, backend="engine_jit")
    assert verify_bundle_file(p) == []
    blob = open(p, "rb").read()
    open(p, "wb").write(blob[:len(blob) // 2])
    f = _one(verify_bundle_file(p), "bundle-file", "layer0.npz")
    assert "refused before any hash comparison" in f.message


def _manifest():
    files = [{"file": "l0.npz", "index": [], "sha256": "0" * 64}]
    return {"format": 1, "backend": "engine_jit",
            "engine_config": {"w_bits": 4, "t": 4},
            "weights_fingerprint": "f" * 16, "n_layers": 1,
            "n_files": 1,
            "layers": {"blocks/0/qlin": {"lead": [], "groups": 1,
                                         "files": files}}}


def test_mut_manifest_missing_key():
    m = _manifest()
    del m["weights_fingerprint"]
    _one(verify_manifest(m), "bundle-manifest", "weights_fingerprint")


def test_mut_manifest_duplicate_slice_index():
    m = _manifest()
    meta = m["layers"]["blocks/0/qlin"]
    meta["lead"] = [2]
    meta["files"] = [
        {"file": "a.npz", "index": [0], "sha256": "0" * 64},
        {"file": "b.npz", "index": [0], "sha256": "1" * 64}]
    m["n_files"] = 2
    _one(verify_manifest(m), "bundle-manifest", "files[1].index")


def test_clean_manifest():
    assert verify_manifest(_manifest()) == []


# -- gate attribution --------------------------------------------------------

def test_gate_cache_publish_refuses_corrupt_plan(cache, monkeypatch):
    """A planner bug (here: injected) is stopped AT PUBLISH — the cache
    never serves the malformed plan, and the failure is attributed to
    the cache-publish gate."""
    real = BatchedTransitiveEngine.plan

    def corrupt(self, w, groups=1):
        p = real(self, w, groups=groups)
        rows = np.array(p.rows, np.int64)
        rows[0, 0, 0] = 1 << p.t
        return dataclasses.replace(p, rows=rows)

    monkeypatch.setattr(BatchedTransitiveEngine, "plan", corrupt)
    w = np.asarray(jax.random.randint(
        jax.random.PRNGKey(3), (8, 16), -8, 8))
    with pytest.raises(PlanVerificationError) as ei:
        cache.get_or_build(w, EngineConfig(w_bits=4, t=4, groups=1))
    assert ei.value.where == "cache-publish"
    assert ei.value.findings[0].rule == "plan-bounds"
    # nothing was published: a healthy rebuild is a MISS, not a hit
    monkeypatch.setattr(BatchedTransitiveEngine, "plan", real)
    cache.get_or_build(w, EngineConfig(w_bits=4, t=4, groups=1))
    assert cache.stats()["hits"] == 0


def test_gate_bundle_load_refuses_before_sha256(cache, tmp_path,
                                                monkeypatch):
    """The acceptance wording, literally: a corrupted bundle file is
    rejected by planlint BEFORE the sha256 check ever reads it."""
    from repro.configs import get_reduced
    from repro.fleet import bundles
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=1),
                       backend="engine_jit")
    raw = Model(cfg).init(jax.random.PRNGKey(0))
    bdir = str(tmp_path / "b")
    manifest = bundles.write_bundles(raw, cfg.quant, bdir)
    victim = next(iter(
        manifest["layers"].values()))["files"][0]["file"]
    vpath = os.path.join(bdir, victim)
    blob = open(vpath, "rb").read()
    open(vpath, "wb").write(blob[:len(blob) // 2])   # truncate

    hashed = []
    real_sha = bundles._sha256
    monkeypatch.setattr(bundles, "_sha256",
                        lambda p: hashed.append(str(p)) or real_sha(p))
    with pytest.raises(PlanVerificationError) as ei:
        bundles.load_bundles(raw, cfg.quant, bdir)
    assert ei.value.where == "bundle-load"
    assert ei.value.findings[0].rule == "bundle-file"
    assert vpath not in hashed, \
        "sha256 ran on the corrupted file before planlint refused it"


def test_gate_swap_staging_refuses_corrupt_dplan(cache):
    """A malformed DevicePlan in a hot-swap generation is refused at
    swap_params staging — it never waits in _staged for the scheduling
    thread to attach."""
    from repro.configs import get_reduced
    from repro.fleet import build_generation
    from repro.launch.specs import serve_config
    from repro.models.model import Model
    from repro.serve import ServeEngine
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=1),
                       backend="engine_jit")
    model = Model(cfg)
    gen0 = build_generation(model, model.init(jax.random.PRNGKey(0)),
                            gen=0)
    gen1 = build_generation(model, model.init(jax.random.PRNGKey(9)),
                            ref=gen0.params, gen=1)
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=16,
                      page_size=4)

    def corrupt(tree):
        from repro.core.engine import DevicePlan
        if isinstance(tree, DevicePlan):
            gi = np.array(tree.gather_idx, np.int64)
            gi[(0,) * gi.ndim] = -1
            return dataclasses.replace(tree, gather_idx=gi)
        if isinstance(tree, dict):
            return {k: corrupt(v) for k, v in tree.items()}
        return tree

    with pytest.raises(PlanVerificationError) as ei:
        eng.swap_params(corrupt(gen1.params))
    assert ei.value.where == "swap-staging"
    assert ei.value.findings[0].rule == "device-bounds"
    assert eng.stats()["swaps_staged"] == 0   # nothing was staged
    eng.swap_params(gen1.params)              # the healthy swap stages
    assert eng.stats()["swaps_staged"] == 1


def test_gates_disabled_by_env(plan, monkeypatch):
    monkeypatch.setenv("REPRO_PLANLINT", "0")
    bad = dataclasses.replace(plan, groups=3)
    planlint.gate_plan(bad, where="anywhere")   # no raise when off


# -- registry ----------------------------------------------------------------

def test_plan_rule_registry_is_loud():
    class Dummy(planlint.PlanRule):
        name = "plan-shape"                    # collides

    with pytest.raises(ValueError, match="already registered"):
        planlint.register_plan_rule(Dummy())
    with pytest.raises(KeyError, match="unknown plan rule"):
        planlint.unregister_plan_rule("no-such-rule")
    assert "plan-schedule-dag" in list_plan_rules()


# -- costcheck: metrics + cross-check ----------------------------------------

def test_jaxpr_cost_scan_weighting_and_pool_tracking():
    import jax.numpy as jnp

    def f(pool, idx, x):
        view = pool.reshape(-1, 4)            # still the pool
        def body(c, i):
            page = view[idx[i]]               # pool gather, xL
            return c + page.sum() + x[i], None
        c, _ = jax.lax.scan(body, 0.0, jnp.arange(8))
        return c

    jx = jax.make_jaxpr(f)(jnp.zeros(64), jnp.zeros(8, jnp.int32),
                           jnp.zeros(8))
    m = costcheck.jaxpr_cost(jx, pool_range=(0, 1))
    assert m.pool_gathers >= 1
    # one (4,)-f32 page per scan iteration, scan length 8
    assert m.pool_gather_bytes == pytest.approx(8 * 4 * 4)
    # the same gather NOT taint-attributed without a pool range
    m0 = costcheck.jaxpr_cost(jx)
    assert m0.pool_gather_bytes == 0 and m0.gather_bytes > 0


def test_jaxpr_cost_counts_loops_and_scatters():
    import jax.numpy as jnp

    def f(a):
        def body(c, i):
            return c.at[i].add(1.0), None
        c, _ = jax.lax.scan(body, a, jnp.arange(4))
        return jax.lax.while_loop(lambda v: v.sum() < 10,
                                  lambda v: v + 1, c)

    m = costcheck.jaxpr_cost(jax.make_jaxpr(f)(jnp.zeros(4)))
    assert m.scatter_in_loop >= 1
    assert m.while_loops == 1
    assert m.peak_live_bytes > 0


def test_crosscheck_costmodel_agrees(plan):
    assert costcheck.crosscheck_costmodel(plan) == []


def test_crosscheck_costmodel_catches_divergence(plan):
    """Dropping a schedule edge breaks the ppe_ops identity — the
    analytical model now budgets ops the schedule doesn't run."""
    s = plan.steps[0]
    cut = _mut_step(plan, 0, **{k: _np(getattr(s, k))[1:]
                                for k in ("tile", "node", "prefix",
                                          "bit")})
    fs = costcheck.crosscheck_costmodel(cut)
    assert len(fs) == 1 and fs[0].rule == "cost-model-agreement"
    assert fs[0].path == "ppe_ops"


def test_plan_cost_fields(plan):
    pc = costcheck.plan_cost(plan)
    assert pc["levels"] == len(plan.steps)
    assert pc["ppe_adds"] == pc["step_edges"] + pc["direct_adds"]


# -- costcheck: budgets ------------------------------------------------------

def test_budget_file_loads_and_validates(tmp_path):
    b = costcheck.load_budgets()
    assert {x["name"] for x in b["budgets"]} >= {
        "live-page-decode", "swap-trace-count"}
    bad = tmp_path / "b.json"
    bad.write_text(json.dumps({"format": 0, "budgets": []}))
    with pytest.raises(ValueError, match="format"):
        costcheck.load_budgets(bad)
    bad.write_text(json.dumps(
        {"format": 1, "budgets": [{"name": "x"}]}))
    with pytest.raises(ValueError, match="missing"):
        costcheck.load_budgets(bad)


def test_live_page_budget_fails_when_hand_broken(cache, tmp_path):
    """The headline asymmetry: the Pallas live-page kernel's pool reads
    do not grow with max_len (budget passes); pointing the SAME budget
    at the oracle paged-decode — which walks the whole page table every
    step — makes it fail, i.e. the budget genuinely measures O(live
    pages) vs O(max_len)."""
    budgets = {"format": 1, "budgets": [
        {"name": "live-page-decode", "program": "paged-attention",
         "metric": "pool_gather_bytes_growth", "max": 1.25},
        {"name": "live-page-decode-broken", "program": "paged-decode",
         "metric": "pool_gather_bytes_growth", "max": 1.25}]}
    p = tmp_path / "budgets.json"
    p.write_text(json.dumps(budgets))
    report, findings = costcheck.check_budgets(
        ["engine_jit"], budgets_path=p)
    by_name = {r["budget"]: r for r in report if "value" in r}
    assert by_name["live-page-decode"]["ok"]
    assert not by_name["live-page-decode-broken"]["ok"]
    assert by_name["live-page-decode-broken"]["value"] == \
        pytest.approx(2.0, rel=0.01)
    assert [f.primitive for f in findings] == ["live-page-decode-broken"]
    assert findings[0].rule == "cost-budget"


def test_swap_trace_budget_fails_when_hand_broken(cache, tmp_path):
    """decode traces across a hot swap: 1 when the new generation is
    pad-aligned (budget passes), 2 when the alignment is skipped and
    the DevicePlan avals drift (budget fails)."""
    budgets = {"format": 1, "budgets": [
        {"name": "swap-trace-count", "backend": "engine_jit",
         "program": "paged-decode-swapped",
         "metric": "decode_jit_traces", "max": 1},
        {"name": "swap-trace-count-broken", "backend": "engine_jit",
         "program": "paged-decode-swapped",
         "metric": "decode_jit_traces", "max": 1, "aligned": False}]}
    p = tmp_path / "budgets.json"
    p.write_text(json.dumps(budgets))
    report, findings = costcheck.check_budgets(
        ["engine_jit"], budgets_path=p)
    by_name = {r["budget"]: r for r in report if "value" in r}
    assert by_name["swap-trace-count"]["value"] == 1.0
    assert by_name["swap-trace-count-broken"]["value"] == 2.0
    assert [f.primitive for f in findings] == ["swap-trace-count-broken"]


# -- lint_plans driver -------------------------------------------------------

def test_lint_plans_clean_on_engine_jit(cache):
    report, findings = planlint.lint_plans(["engine_jit"])
    assert findings == [], [f.format() for f in findings]
    assert report and report[0]["backend"] == "engine_jit"
