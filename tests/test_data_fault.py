"""Data determinism + fault-tolerance policy units."""
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import SyntheticLM
from repro.distributed.fault import (Preemption, StragglerMonitor,
                                     run_with_restarts)


def test_data_restart_exact():
    cfg = get_reduced("smollm_135m")
    d1 = SyntheticLM(cfg, 32, 8, seed=1)
    d2 = SyntheticLM(cfg, 32, 8, seed=1)
    for step in (0, 5, 17):
        b1, b2 = d1.batch(step), d2.batch(step)
        np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                      np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(d1.batch(0)["tokens"]),
                              np.asarray(d1.batch(1)["tokens"]))


def test_data_has_learnable_structure():
    cfg = get_reduced("smollm_135m")
    d = SyntheticLM(cfg, 128, 16, seed=0)
    b = d.batch(0)
    toks = np.asarray(b["tokens"]).ravel()
    labs = np.asarray(b["labels"]).ravel()
    match = (labs == d.succ[toks]).mean()
    assert match > 0.5            # bigram structure present


def test_data_microbatch_layout():
    cfg = get_reduced("smollm_135m")
    b = SyntheticLM(cfg, 16, 8, seed=0).batch(0, grad_accum=4)
    assert b["tokens"].shape == (4, 2, 16)


def test_straggler_monitor():
    import time
    mon = StragglerMonitor(threshold=3.0, window=16)
    for _ in range(10):
        mon.start()
        time.sleep(0.002)
        assert mon.stop() is False
    mon.start()
    time.sleep(0.05)
    assert mon.stop() is True
    assert mon.stragglers == 1


def test_run_with_restarts_recovers():
    calls = []

    def loop(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise Preemption("injected")
        return 123

    final, restarts = run_with_restarts(loop, max_restarts=3)
    assert final == 123 and restarts == 2 and calls == [0, 1, 2]


def test_run_with_restarts_gives_up():
    def loop(attempt):
        raise RuntimeError("persistent")
    with pytest.raises(RuntimeError):
        run_with_restarts(loop, max_restarts=1)
