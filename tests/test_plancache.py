"""Plan-cache subsystem tests (core/plancache.py + the rewired serving path).

Covers the ISSUE-2 acceptance surface: hit/miss/eviction/invalidation
counters, invalidation when ``qw`` changes, bit-exactness of cached vs
freshly-planned outputs (incl. the single-batched-plan grouped path), the
offline ``precompile`` pytree walk, and ``backend="engine"`` under ``jit`` +
``vmap``.
"""
import numpy as np
import pytest

from repro.core.engine import BatchedTransitiveEngine
from repro.core.plancache import (PlanCache, default_cache, precompile,
                                  set_default_cache, weight_fingerprint)


@pytest.fixture
def cache():
    """Fresh process-default cache per test; restores the previous one."""
    c = PlanCache(capacity=64)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


def _w(rng, n=9, k=32, bits=4):
    lo = 1 << (bits - 1)
    return rng.integers(-lo, lo, size=(n, k))


# -- counters ---------------------------------------------------------------

def test_hit_miss_counters(rng):
    c = PlanCache()
    w = _w(rng)
    p1 = c.get_or_build(w, 4, 8)
    p2 = c.get_or_build(w, 4, 8)
    assert p1 is p2
    assert c.stats() == {"hits": 1, "misses": 1, "evictions": 0,
                         "invalidations": 0, "size": 1, "capacity": 256,
                         "backends": {}}
    # a different (bits, t) is a different plan for the same bytes
    c.get_or_build(w, 4, 4)
    assert c.stats()["misses"] == 2 and len(c) == 2


def test_lru_eviction_order(rng):
    c = PlanCache(capacity=2)
    w1, w2, w3 = (_w(rng) for _ in range(3))
    c.get_or_build(w1, 4, 8)
    c.get_or_build(w2, 4, 8)
    c.get_or_build(w1, 4, 8)          # touch w1 -> w2 is now LRU
    c.get_or_build(w3, 4, 8)          # evicts w2
    assert c.stats()["evictions"] == 1
    c.get_or_build(w1, 4, 8)          # still resident
    assert c.stats()["hits"] == 2
    c.get_or_build(w2, 4, 8)          # gone -> rebuild
    assert c.stats()["misses"] == 4


def test_invalidation_on_weight_update(rng):
    c = PlanCache()
    w = _w(rng)
    c.get_or_build(w, 4, 8)
    c.get_or_build(w, 4, 4)
    # content change -> different fingerprint -> natural miss, no stale hit
    w2 = w.copy()
    w2[0, 0] ^= 1
    c.get_or_build(w2, 4, 8)
    assert c.stats()["misses"] == 3 and c.stats()["hits"] == 0
    # explicit invalidation drops every (bits, t) entry of the old weight
    assert c.invalidate(w) == 2
    assert c.stats()["invalidations"] == 2 and len(c) == 1
    c.get_or_build(w, 4, 8)
    assert c.stats()["misses"] == 4


def test_fingerprint_covers_shape_and_dtype(rng):
    w = _w(rng, n=4, k=16).astype(np.int8)
    assert weight_fingerprint(w) == weight_fingerprint(w.copy())
    assert weight_fingerprint(w) != weight_fingerprint(w.astype(np.int64))
    assert weight_fingerprint(w) != weight_fingerprint(w.reshape(8, 8))


def test_same_values_any_dtype_one_entry(rng):
    """The cache canonicalises dtype before fingerprinting: int8 callback
    views and int64 precompile walks of the same weight share one plan."""
    c = PlanCache()
    w = _w(rng, bits=8)
    p64 = c.get_or_build(w.astype(np.int64), 8, 8)
    p8 = c.get_or_build(w.astype(np.int8), 8, 8)
    assert p64 is p8
    assert c.stats()["misses"] == 1 and c.stats()["hits"] == 1
    assert c.invalidate(w.astype(np.int16)) == 1     # any dtype, same bytes
    with pytest.raises(ValueError):                   # wrap guard is loud
        c.get_or_build(np.full((2, 8), 1000), 8, 8)


def test_clear_and_reset(rng):
    c = PlanCache()
    c.get_or_build(_w(rng), 4, 8)
    c.clear()
    assert len(c) == 0 and c.stats()["invalidations"] == 1
    c.reset_stats()
    assert c.stats()["misses"] == 0


# -- bit-exactness ----------------------------------------------------------

def test_cached_run_bit_exact(rng):
    c = PlanCache()
    w = _w(rng, n=11, k=48, bits=8)
    want = None
    for seed in range(3):
        x = np.random.default_rng(seed).integers(-128, 128, (48, 7))
        got = c.run(w, x, 8, 8)
        want = w.astype(np.int64) @ x.astype(np.int64)
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, BatchedTransitiveEngine(8, 8)(w, x))
    assert c.stats()["misses"] == 1 and c.stats()["hits"] == 2


def test_grouped_plan_single_batched_build(rng):
    """All G groups plan as ONE batched tile axis and stay bit-exact."""
    n, G, g, m = 6, 4, 16, 5
    w = _w(rng, n=n, k=G * g, bits=4)
    x = rng.integers(-128, 128, (G * g, m))
    c = PlanCache()
    part = c.run(w, x, 4, 8, groups=G)                  # (N, G, M)
    want = np.einsum("ngi,gim->ngm",
                     w.reshape(n, G, g).astype(np.int64),
                     x.reshape(G, g, m).astype(np.int64))
    np.testing.assert_array_equal(part, want)
    assert c.stats() == {"hits": 0, "misses": 1, "evictions": 0,
                         "invalidations": 0, "size": 1, "capacity": 256,
                         "backends": {}}


# -- the serving path (qlinear callbacks) -----------------------------------

@pytest.mark.parametrize("group", [0, 64])
def test_engine_path_uses_cache(cache, group):
    """linear_apply backend="engine" plans once per weight, then run-only —
    including the grouped case (one batched plan, not one per group)."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=group,
                      backend="engine")
    p = linear_init(jax.random.PRNGKey(0), 128, 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 128), jnp.float32)
    y0 = linear_apply(p, x, cfg)
    for _ in range(2):
        linear_apply(p, x, cfg)
    s = cache.stats()
    assert s["misses"] == 1 and s["hits"] == 2
    y_int = linear_apply(p, x, cfg.with_(backend="int_dot"))
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y_int))


@pytest.mark.parametrize("group", [0, 64])
def test_engine_path_under_jit_vmap(cache, group):
    """backend="engine" composes with jit + vmap, matches int_dot there."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=group)
    p = linear_init(jax.random.PRNGKey(0), 128, 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 128), jnp.float32)

    def f(path):
        return jax.jit(jax.vmap(
            lambda xi: linear_apply(p, xi, cfg.with_(backend=path))))(x)
    np.testing.assert_array_equal(np.asarray(f("engine")),
                                  np.asarray(f("int_dot")))
    assert cache.stats()["misses"] == 1


# -- offline precompile -----------------------------------------------------

def test_precompile_walks_nested_and_stacked_params(cache):
    """precompile finds {qw, sg} leaves under nesting and vmap-stacked
    leading axes, builds each plan once, and makes serving all-hits."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64, backend="engine")
    flat = linear_init(jax.random.PRNGKey(0), 128, 16, cfg)
    stacked = jax.vmap(lambda k: linear_init(k, 128, 16, cfg))(
        jax.random.split(jax.random.PRNGKey(1), 3))
    params = {"blocks": {"b0": {"up": stacked}}, "head": flat,
              "norm": jnp.ones((4,))}
    stats = precompile(params, cfg, cache=cache)
    assert stats == {"layers": 2, "plans": 4, "built": 4}
    assert cache.stats()["misses"] == 4 and len(cache) == 4
    # every subsequent forward is a pure hit — incl. the stacked weights
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 128), jnp.float32)
    linear_apply(flat, x, cfg)
    for r in range(3):
        p_r = jax.tree.map(lambda a: a[r], stacked)
        linear_apply(p_r, x, cfg)
    s = cache.stats()
    assert s["misses"] == 4 and s["hits"] == 4


def test_model_precompile_plans_end_to_end(cache):
    """Model.precompile_plans warms every PTQ layer; prefill+decode then
    run plan-free (misses == distinct quantized weights)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.launch.specs import serve_config
    from repro.models.model import Model

    cfg = serve_config(get_reduced("smollm-135m"), w_bits=4, backend="engine")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stats = model.precompile_plans(params)
    assert stats["built"] == stats["plans"] > 0
    misses = cache.stats()["misses"]
    assert misses == stats["built"]

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                          cfg.vocab, jnp.int32)}
    logits, caches = jax.jit(lambda p, b: model.prefill(p, b, 8))(params,
                                                                 batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits, _ = jax.jit(model.decode_step)(params, caches, tok, jnp.int32(4))
    jax.block_until_ready(logits)
    s = cache.stats()
    assert s["misses"] == misses, "decode re-planned a weight"
    assert s["hits"] > 0


# -- version-tag fast keys --------------------------------------------------

def test_version_tag_skips_content_hashing(rng, monkeypatch):
    """Version-keyed lookups never hash the weight bytes after the initial
    build (the ROADMAP fast-key item); content-keyed lookups hash every
    call."""
    import repro.core.plancache as PC
    calls = {"n": 0}
    real = PC.weight_fingerprint

    def counting(qw):
        calls["n"] += 1
        return real(qw)
    monkeypatch.setattr(PC, "weight_fingerprint", counting)

    c = PlanCache()
    w = _w(rng)
    c.get_or_build(w, 4, 8, version=("layer0", 0))   # build: hashes once
    assert calls["n"] == 1
    for _ in range(5):
        c.get_or_build(w, 4, 8, version=("layer0", 0))
    assert calls["n"] == 1                           # hits: zero hashing
    assert c.stats()["hits"] == 5 and c.stats()["misses"] == 1
    c.get_or_build(w, 4, 8)                          # content key: hashes
    assert calls["n"] == 2


def test_version_tag_distinct_tags_distinct_plans(rng):
    c = PlanCache()
    w = _w(rng)
    p0 = c.get_or_build(w, 4, 8, version=("l", 0))
    p1 = c.get_or_build(w, 4, 8, version=("l", 1))  # new tag -> new entry
    assert p0 is not p1 and c.stats()["misses"] == 2


def test_invalidate_finds_version_keyed_entries(rng):
    """invalidate stays content-based: it drops version-keyed entries of
    the same weight bytes too (the fingerprint is stored at build time)."""
    c = PlanCache()
    w = _w(rng)
    c.get_or_build(w, 4, 8, version=("l", 0))
    c.get_or_build(w, 4, 8)                          # content-keyed twin
    c.get_or_build(_w(rng), 4, 8, version=("m", 0))  # different weight
    assert c.invalidate(w) == 2
    assert len(c) == 1 and c.stats()["invalidations"] == 2


def test_invalidate_version_covers_in_place_weight_update(rng):
    """A reused tag over updated bytes would serve the stale plan; the
    update flow is invalidate_version (old bytes gone) or a bumped tag."""
    c = PlanCache()
    w_old = _w(rng)
    stale = c.get_or_build(w_old, 4, 8, version="layer0")
    w_new = w_old.copy()
    w_new[0, 0] ^= 1
    # content invalidation with the NEW bytes cannot find the old entry
    assert c.invalidate(w_new) == 0
    assert c.get_or_build(w_new, 4, 8, version="layer0") is stale
    # ... invalidate_version can
    assert c.invalidate_version("layer0") == 1
    fresh = c.get_or_build(w_new, 4, 8, version="layer0")
    assert fresh is not stale and c.stats()["misses"] == 2
    # a bumped tag (the step-counter scheme) never sees the stale entry
    assert c.get_or_build(w_new, 4, 8, version=("layer0", 1)) is not stale


# -- device plans through the cache -----------------------------------------

def test_get_or_build_device_memoised(rng):
    """The DevicePlan is compiled once and the same pytree returned (so
    jit caches keyed on leaf identity/shape stay warm)."""
    import jax.numpy as jnp
    from repro.core.engine import run_device_jit
    c = PlanCache()
    w = _w(rng, n=6, k=32, bits=4)
    d1 = c.get_or_build_device(w, 4, 8)
    d2 = c.get_or_build_device(w, 4, 8)
    assert d1 is d2
    assert c.stats()["misses"] == 1 and c.stats()["hits"] == 1
    x = rng.integers(-128, 128, (32, 3))
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(d1, jnp.asarray(x))),
        w.astype(np.int64) @ x.astype(np.int64))
    # host plan lookups share the same entry
    assert c.get_or_build(w, 4, 8) is not None
    assert c.stats()["misses"] == 1


def test_attach_device_plans_stacked_and_flat(cache):
    """attach_device_plans embeds a dplan per PTQ layer dict, stacking
    plans of vmap-stacked weights along the same leading axis."""
    import jax
    from repro.core.plancache import attach_device_plans
    from repro.quant import QuantConfig, linear_init
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64,
                      backend="engine_jit")
    flat = linear_init(jax.random.PRNGKey(0), 128, 16, cfg)
    stacked = jax.vmap(lambda k: linear_init(k, 128, 16, cfg))(
        jax.random.split(jax.random.PRNGKey(1), 3))
    params = {"blocks": {"b0": stacked}, "head": flat}
    out = attach_device_plans(params, cfg, cache=cache)
    assert out["head"]["dplan"].level_src.ndim == 2
    assert out["blocks"]["b0"]["dplan"].level_src.shape[0] == 3
    assert out["blocks"]["b0"]["dplan"].groups == 2
    # the original params are untouched; plans were built through the cache
    assert "dplan" not in params["head"]
    assert cache.stats()["misses"] == 4


def test_model_attach_device_plans_end_to_end(cache):
    """engine_jit serving: plans attached to the params ride the block
    scan; prefill + decode are bit-exact with int_dot and lower with zero
    pure_callback."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.launch.specs import serve_config
    from repro.models.model import Model

    cfg = serve_config(get_reduced("smollm-135m"), w_bits=4,
                       backend="engine_jit")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    stats = model.precompile_plans(params)
    assert stats["built"] == stats["plans"] > 0
    params_d = model.attach_device_plans(params)
    assert cache.stats()["misses"] == stats["built"]   # attach re-used them

    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 4), 0,
                                          cfg.vocab, jnp.int32)}
    prefill = jax.jit(lambda p, b: model.prefill(p, b, 8))
    logits, caches = prefill(params_d, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, _ = jax.jit(model.decode_step)(params_d, caches, tok,
                                            jnp.int32(4))
    jax.block_until_ready(logits2)
    assert cache.stats()["misses"] == stats["built"], "decode re-planned"

    # bit-exact with the int_dot reference model on the same params
    cfg_i = serve_config(get_reduced("smollm-135m"), w_bits=4,
                         backend="int_dot")
    logits_i, _ = jax.jit(lambda p, b: Model(cfg_i).prefill(p, b, 8))(
        params, batch)
    np.testing.assert_array_equal(np.asarray(logits), np.asarray(logits_i))

    from repro import analysis
    analysis.assert_clean(lambda p, b: model.prefill(p, b, 8),
                          params_d, batch, name="prefill")


def test_default_cache_swap_restores():
    c = PlanCache(capacity=1)
    prev = set_default_cache(c)
    try:
        assert default_cache() is c
    finally:
        set_default_cache(prev)
    assert default_cache() is prev


def test_capacity_validation():
    with pytest.raises(ValueError):
        PlanCache(capacity=0)
    with pytest.raises(ValueError):
        PlanCache().get_or_build(np.zeros((2, 2, 8), np.int8), 4, 8)


def test_precompile_reserves_capacity(cache):
    """A model with more weights than capacity must not thrash its own
    warmup: precompile grows the cache before building."""
    import jax
    from repro.quant import QuantConfig, linear_init
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=0, backend="engine")
    small = PlanCache(capacity=2)
    stacked = jax.vmap(lambda k: linear_init(k, 32, 8, cfg))(
        jax.random.split(jax.random.PRNGKey(0), 5))
    stats = precompile({"b": stacked}, cfg, cache=small)
    assert stats == {"layers": 1, "plans": 5, "built": 5}
    assert small.capacity >= 5 and len(small) == 5
    assert small.stats()["evictions"] == 0


# -- thread-safety under concurrent serving (the lock-scope fix) -------------

class _Barrier:
    """threading.Barrier with a pytest-friendly timeout."""

    def __init__(self, n):
        import threading
        self.b = threading.Barrier(n, timeout=30)

    def wait(self):
        self.b.wait()


def _run_threads(fns):
    """Run callables concurrently; re-raise the first worker exception."""
    import threading
    errs = []

    def wrap(fn):
        def run():
            try:
                fn()
            except BaseException as e:   # noqa: BLE001 — reported below
                errs.append(e)
        return run

    ts = [threading.Thread(target=wrap(fn)) for fn in fns]
    for t in ts:
        t.start()
    for t in ts:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in ts), "worker thread hung"
    if errs:
        raise errs[0]
    return errs


def test_threaded_same_weight_single_build(rng, monkeypatch):
    """N threads racing the same cold weight coalesce on ONE build: the
    plan body runs once, misses == 1, every other lookup counts a hit,
    and all threads get the same entry. (Before the lock-scope fix the
    build ran under the cache lock, so this was serialized-but-single;
    the fix must keep it single WITHOUT the lock.)"""
    import time as _time
    import repro.core.plancache as PC
    builds = []
    real_plan = PC.BatchedTransitiveEngine.plan

    def slow_plan(self, qw, groups=1):
        builds.append(1)
        _time.sleep(0.05)          # widen the race window
        return real_plan(self, qw, groups=groups)
    monkeypatch.setattr(PC.BatchedTransitiveEngine, "plan", slow_plan)

    c = PlanCache()
    w = _w(rng)
    n = 8
    bar = _Barrier(n)
    results = [None] * n

    def worker(i):
        def run():
            bar.wait()
            results[i] = c.get_or_build(w, 4, 8)
        return run
    _run_threads([worker(i) for i in range(n)])
    assert len(builds) == 1
    assert all(r is results[0] and r is not None for r in results)
    s = c.stats()
    assert s["misses"] == 1 and s["hits"] == n - 1
    assert len(c) == 1


def test_threaded_distinct_weights_no_lost_entries(rng):
    """Concurrent builds of DISTINCT weights must not lose entries or
    double-count: misses == distinct weights, hits + misses == lookups."""
    n_weights, per = 6, 4
    ws = [_w(rng) for _ in range(n_weights)]
    c = PlanCache()
    bar = _Barrier(n_weights * per)

    def worker(w):
        def run():
            bar.wait()
            for _ in range(3):
                c.get_or_build(w, 4, 8)
        return run
    _run_threads([worker(w) for w in ws for _ in range(per)])
    s = c.stats()
    lookups = n_weights * per * 3
    assert s["misses"] == n_weights
    assert s["hits"] == lookups - n_weights
    assert len(c) == n_weights
    # every entry actually landed and runs bit-exact
    x = rng.integers(-128, 128, (32, 3))
    for w in ws:
        np.testing.assert_array_equal(
            c.run(w, x, 4, 8), w.astype(np.int64) @ x.astype(np.int64))


def test_cold_build_does_not_block_other_keys(rng, monkeypatch):
    """The lock-scope property itself: while one thread is inside a slow
    cold build, a lookup of a DIFFERENT key completes — the build runs
    outside the cache lock."""
    import threading
    import repro.core.plancache as PC
    w_slow, w_fast = _w(rng), _w(rng)
    slow_fp = weight_fingerprint(w_slow.astype(np.int8))
    gate = threading.Event()
    entered = threading.Event()
    real_plan = PC.BatchedTransitiveEngine.plan

    def gated_plan(self, qw, groups=1):
        if weight_fingerprint(qw.astype(np.int8)) == slow_fp:
            entered.set()
            assert gate.wait(timeout=30), "test gate never opened"
        return real_plan(self, qw, groups=groups)
    monkeypatch.setattr(PC.BatchedTransitiveEngine, "plan", gated_plan)

    c = PlanCache()
    t = threading.Thread(target=lambda: c.get_or_build(w_slow, 4, 8))
    t.start()
    try:
        assert entered.wait(timeout=30)
        # the slow build holds the pending slot, NOT the lock: this
        # returns immediately rather than deadlocking the test
        c.get_or_build(w_fast, 4, 8)
        assert c.stats()["misses"] == 2 and len(c) == 1
    finally:
        gate.set()
        t.join(timeout=30)
    assert not t.is_alive()
    assert len(c) == 2 and c.stats()["hits"] == 0


def test_builder_failure_releases_waiters(rng, monkeypatch):
    """A failed build must not wedge concurrent waiters of the same key:
    they retry, one becomes the new builder, and the entry lands."""
    import threading
    import repro.core.plancache as PC
    fail_once = {"armed": True}
    first_inside = threading.Event()
    waiter_waiting = threading.Event()
    real_plan = PC.BatchedTransitiveEngine.plan

    def flaky_plan(self, qw, groups=1):
        if fail_once["armed"]:
            fail_once["armed"] = False
            first_inside.set()
            # don't fail until the second thread is parked on the event
            assert waiter_waiting.wait(timeout=30)
            raise RuntimeError("simulated plan-build failure")
        return real_plan(self, qw, groups=groups)
    monkeypatch.setattr(PC.BatchedTransitiveEngine, "plan", flaky_plan)

    c = PlanCache()
    w = _w(rng)
    outcome = {}

    def first():
        try:
            c.get_or_build(w, 4, 8)
        except RuntimeError as e:
            outcome["first"] = e

    def second():
        assert first_inside.wait(timeout=30)
        waiter_waiting.set()
        outcome["second"] = c.get_or_build(w, 4, 8)

    _run_threads([first, second])
    # the builder's caller saw the exception; the waiter recovered
    assert isinstance(outcome.get("first"), RuntimeError)
    assert outcome.get("second") is not None
    assert len(c) == 1
    # both lookups counted as misses (each ran a build attempt)
    assert c.stats()["misses"] == 2 and c.stats()["hits"] == 0
    # and the key is fully healthy afterwards
    assert c.get_or_build(w, 4, 8) is outcome["second"]
    assert c.stats()["hits"] == 1


def _gated_build(monkeypatch, gate, entered):
    """Monkeypatch the plan body to park inside the build until ``gate``
    opens, signalling ``entered`` first (the pending-slot race widener)."""
    import repro.core.plancache as PC
    real_plan = PC.BatchedTransitiveEngine.plan

    def gated(self, qw, groups=1):
        entered.set()
        assert gate.wait(timeout=30), "test gate never opened"
        return real_plan(self, qw, groups=groups)
    monkeypatch.setattr(PC.BatchedTransitiveEngine, "plan", gated)


def test_invalidate_during_pending_build_not_resurrected(rng, monkeypatch):
    """The hot-swap race (PR 9): weights are invalidated WHILE their plan
    is still building on another thread. The finishing build must not
    publish the now-dead entry — a lookup after the dust settles rebuilds
    instead of hitting a resurrected stale plan."""
    import threading
    gate, entered = threading.Event(), threading.Event()
    _gated_build(monkeypatch, gate, entered)

    c = PlanCache()
    w = _w(rng)
    got = {}
    t = threading.Thread(target=lambda: got.update(
        plan=c.get_or_build(w, 4, 8)))
    t.start()
    try:
        assert entered.wait(timeout=30)
        # the builder is parked inside the build: invalidate its weight
        assert c.invalidate(w) == 0        # nothing published yet ...
    finally:
        gate.set()
        t.join(timeout=30)
    assert not t.is_alive()
    # ... but the tombstone stopped the publish: the build's own caller
    # still got a usable plan, the cache stayed empty, and the discard
    # was counted as the invalidation it is
    assert got["plan"] is not None
    assert len(c) == 0
    assert c.stats()["invalidations"] == 1
    # next lookup is a fresh miss (no resurrection), and THAT entry sticks
    gate.set()
    fresh = c.get_or_build(w, 4, 8)
    assert fresh is not got["plan"]
    assert len(c) == 1 and c.stats()["misses"] == 2
    assert c.get_or_build(w, 4, 8) is fresh


def test_invalidate_version_during_pending_build_not_resurrected(
        rng, monkeypatch):
    """Same race through the version-keyed fast path: invalidate_version
    lands while the tagged build is in flight; the tag must come back
    empty, not resurrected."""
    import threading
    gate, entered = threading.Event(), threading.Event()
    _gated_build(monkeypatch, gate, entered)

    c = PlanCache()
    w = _w(rng)
    got = {}
    t = threading.Thread(target=lambda: got.update(
        plan=c.get_or_build(w, 4, 8, version="layer0")))
    t.start()
    try:
        assert entered.wait(timeout=30)
        assert c.invalidate_version("layer0") == 0
    finally:
        gate.set()
        t.join(timeout=30)
    assert not t.is_alive()
    assert got["plan"] is not None
    assert len(c) == 0 and c.stats()["invalidations"] == 1
    w_new = w.copy()
    w_new[0, 0] ^= 1                       # the in-place weight update
    fresh = c.get_or_build(w_new, 4, 8, version="layer0")
    assert fresh is not got["plan"] and len(c) == 1
