"""Pallas kernel sweeps (interpret mode) vs pure-jnp oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("m,n,k", [(8, 8, 16), (16, 24, 32), (128, 64, 256),
                                   (130, 70, 512), (1, 8, 64)])
@pytest.mark.parametrize("wbits,t", [(8, 8), (4, 8), (8, 4), (2, 8)])
def test_transitive_gemm_sweep(m, n, k, wbits, t, rng):
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    qw = rng.integers(-(1 << (wbits - 1)), 1 << (wbits - 1),
                      (n, k)).astype(np.int8)
    want = qx.astype(np.int64) @ qw.astype(np.int64).T
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=wbits, t=t))
    np.testing.assert_array_equal(got, want)


def test_transitive_gemm_split_vs_full_lut(rng):
    """Beyond-paper split-LUT must agree with the monolithic 2^T LUT."""
    from repro.kernels.transitive_gemm import transitive_gemm_pallas
    qx = rng.integers(-128, 128, (16, 64)).astype(np.int8)
    qw = rng.integers(-8, 8, (16, 64)).astype(np.int8)
    a = transitive_gemm_pallas(jnp.asarray(qx), jnp.asarray(qw), w_bits=4,
                               t=8, bm=8, bn=8, bk=8, split_lut=True)
    b = transitive_gemm_pallas(jnp.asarray(qx), jnp.asarray(qw), w_bits=4,
                               t=8, bm=8, bn=8, bk=8, split_lut=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_transitive_gemm_batched(rng):
    qx = rng.integers(-128, 128, (2, 5, 32)).astype(np.int8)
    qw = rng.integers(-8, 8, (12, 32)).astype(np.int8)
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=4, t=8))
    want = np.einsum("bsk,nk->bsn", qx.astype(np.int64), qw.astype(np.int64))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("m,n,k,g", [(128, 128, 512, 128), (8, 16, 256, 64),
                                     (130, 200, 384, 128)])
def test_w4a8_gemm_sweep(m, n, k, g, rng):
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    sx = rng.uniform(0.5, 2.0, (m, 1)).astype(np.float32)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    sg = rng.uniform(0.5, 2.0, (n, k // g)).astype(np.float32)
    want = np.asarray(ref.w4a8_matmul_ref(*map(jnp.asarray,
                                               (qx, sx, qw, sg))))
    got = np.asarray(ops.w4a8_gemm(*map(jnp.asarray, (qx, sx, qw, sg)),
                                   group=g))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-2)


@pytest.mark.parametrize("b,s,d", [(8, 512, 256), (1, 64, 32), (2, 256, 512)])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_rg_lru_sweep(b, s, d, dtype, rng):
    x = rng.standard_normal((b, s, d)).astype(np.float32)
    a = rng.uniform(0.8, 0.999, (b, s, d)).astype(np.float32)
    h0 = rng.standard_normal((b, d)).astype(np.float32)
    xs, as_, h0s = (jnp.asarray(x, dtype), jnp.asarray(a, dtype),
                    jnp.asarray(h0, dtype))
    want = np.asarray(ref.rg_lru_ref(xs, as_, h0s), np.float32)
    got = np.asarray(ops.rg_lru(xs, as_, h0s), np.float32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_lut_build_matches_subset_sums(rng):
    xt = jnp.asarray(rng.integers(-50, 50, (5, 8)), jnp.int32)
    lut = np.asarray(ref.lut_build_ref(xt))
    x = np.asarray(xt)
    for p in [0, 1, 5, 128, 255, 170]:
        bits = [b for b in range(8) if (p >> b) & 1]
        np.testing.assert_array_equal(lut[:, p], x[:, bits].sum(-1))
