"""Cost/energy/area model checks against the paper's published numbers."""
import pytest

from repro.core.costmodel import (AntModel, BitFusionModel, BitVertModel,
                                  OliveModel, TransitiveArrayModel,
                                  core_area_mm2, random_subtile_profile)
from repro.core.workloads import llama_fc_gemms, llama_attention_gemms


def test_area_matches_paper_table2():
    areas = core_area_mm2()
    # Table 2 values (mm^2)
    want = {"transarray": 0.443, "bitfusion": 0.491, "ant": 0.484,
            "olive": 0.490, "bitvert": 0.473, "tender": 0.474}
    for k, v in want.items():
        assert abs(areas[k] - v) < 0.01, (k, areas[k], v)
    assert areas["transarray"] == min(areas.values())   # paper: lowest core


@pytest.fixture(scope="module")
def runs():
    g8 = llama_fc_gemms("llama1-7b", w_bits=8)
    g4 = llama_fc_gemms("llama1-7b", w_bits=4)
    return {
        "ta8": TransitiveArrayModel(random_subtile_profile(8), 8).run(g8),
        "ta4": TransitiveArrayModel(random_subtile_profile(4), 4).run(g4),
        "ant": AntModel().run(g8),
        "olive": OliveModel().run(g8),
        "bitvert": BitVertModel().run(g8),
        "bitfusion": BitFusionModel().run(g8),
    }


def test_iso_precision_speedups(runs):
    """Paper Sec. 5.5: TA-8b ~2.47x ANT, ~3.75x Olive, ~1.99x BitVert.
    The modeled ratios must land in the right bands."""
    assert 1.7 < runs["ta8"].speedup_over(runs["ant"]) < 3.3
    assert 2.6 < runs["ta8"].speedup_over(runs["olive"]) < 5.0
    assert 1.3 < runs["ta8"].speedup_over(runs["bitvert"]) < 2.7


def test_iso_accuracy_speedups(runs):
    """Paper: TA-4b ~4.91x ANT, ~7.46x Olive, ~3.97x BitVert."""
    assert 3.4 < runs["ta4"].speedup_over(runs["ant"]) < 6.5
    assert 5.2 < runs["ta4"].speedup_over(runs["olive"]) < 9.5
    assert 2.6 < runs["ta4"].speedup_over(runs["bitvert"]) < 5.2


def test_energy_direction(runs):
    """TA-4b is more energy-efficient than every baseline (Fig. 10)."""
    for k in ("ant", "olive", "bitfusion"):
        assert runs[k].energy.total > runs["ta4"].energy.total, k


def test_buffer_dominates_ta_breakdown(runs):
    """Fig. 11: buffers are TA's largest energy component."""
    e = runs["ta4"].energy
    assert e.buffer > e.pe and e.buffer > e.dram


def test_attention_speedup_positive(runs):
    """Fig. 12: TA keeps a speedup on attention GEMMs; at seq 2048 both
    designs are near compute-bound in our DRAM model so the compression
    toward 1.54x the paper reports (their richer memory simulator) shows
    up only partially — see EXPERIMENTS.md §Paper-validation."""
    att = llama_attention_gemms("llama1-7b")
    ta = TransitiveArrayModel(random_subtile_profile(8), 8).run(att)
    ant = AntModel().run(att)
    s_att = ta.speedup_over(ant)
    s_fc = runs["ta8"].speedup_over(runs["ant"])
    assert 1.0 <= s_att <= s_fc * 1.35


def test_profile_matches_paper_stats():
    p = random_subtile_profile(8)
    assert 150 < p.ppe_ops < 180       # ~162 unique nodes + bridges
    assert 250 < p.ape_ops <= 256
    assert p.cycles >= 32              # >= APE floor of n_rows/T
