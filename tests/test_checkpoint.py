"""Checkpoint: roundtrip, atomicity, GC, async, restart discovery."""
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.distributed import checkpoint as C


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (16, 8)),
                       "blocks": {"b0": jnp.arange(10, dtype=jnp.int32)}},
            "opt": {"m": jnp.ones((16, 8)), "count": jnp.int32(7)},
            "step": jnp.int32(42)}


def test_roundtrip(tmp_path):
    t = _tree()
    C.save(str(tmp_path), 100, t)
    assert C.latest_step(str(tmp_path)) == 100
    t2 = C.restore(str(tmp_path), 100, jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(t2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_manager_gc_and_latest(tmp_path):
    mgr = C.CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (10, 20, 30):
        mgr.save(s, _tree(s))
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000020", "step_00000030"]
    restored, step = mgr.restore_latest(jax.eval_shape(lambda: _tree()))
    assert step == 30
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(_tree(30)["params"]["w"]))


def test_async_save(tmp_path):
    mgr = C.CheckpointManager(str(tmp_path), keep=2, async_save=True)
    mgr.save(5, _tree())
    mgr.wait()
    assert C.latest_step(str(tmp_path)) == 5


def test_partial_write_invisible(tmp_path):
    """A .tmp- dir (crashed mid-save) is never reported as latest."""
    os.makedirs(tmp_path / ".tmp-step_00000099")
    assert C.latest_step(str(tmp_path)) is None
    C.save(str(tmp_path), 7, _tree())
    assert C.latest_step(str(tmp_path)) == 7
