"""Tracelint (ISSUE 7): the static-analysis pass and its rule registry.

Three tiers in one module:

* registry + walker mechanics: ``register_rule`` duplicate/replace
  semantics, unknown-name errors that list the registry, recursive
  equation iteration through ``scan``/``cond``/``pjit`` sub-jaxprs with
  loop membership and inherited ``jax.named_scope`` scopes.
* a positive control per rule — a deliberately violating program each
  rule MUST flag (the analyzer's own acceptance criterion: a lint gate
  that cannot fire is weaker than no gate).
* the public surface: ``assert_clean`` raises with primitive + equation
  path, baselines suppress, ``lint_backend`` honors ``lint_exempt``
  capability tags, and a real backend's program set lints clean
  end-to-end.
"""
import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
import pytest

from repro import analysis
from repro.analysis import rules as R
from repro.analysis.baseline import (load_baseline, save_baseline,
                                     split_baselined)
from repro.analysis.rules import aliased_args
from repro.analysis.walker import iter_eqns


# -- registry ----------------------------------------------------------------

class _DummyRule(R.Rule):
    name = "dummy-test-rule"
    description = "registry test fixture"

    def check(self, prog):
        return []


def test_registry_duplicate_is_loud_and_replace_works():
    r1, r2 = _DummyRule(), _DummyRule()
    R.register_rule(r1)
    try:
        with pytest.raises(ValueError, match="already registered"):
            R.register_rule(r2)
        assert R.register_rule(r2, replace=True) is r2
        assert R.get_rule("dummy-test-rule") is r2
    finally:
        R.unregister_rule("dummy-test-rule")
    assert "dummy-test-rule" not in R.list_rules()


def test_registry_unknown_names_list_registry():
    with pytest.raises(KeyError, match="no-host-callback"):
        R.get_rule("no-such-rule")
    with pytest.raises(KeyError, match="registered rules"):
        R.unregister_rule("no-such-rule")


def test_rule_must_declare_name():
    class Nameless(R.Rule):
        def check(self, prog):
            return []
    with pytest.raises(ValueError, match="name"):
        R.register_rule(Nameless())


def test_builtin_rules_all_registered():
    names = R.list_rules()
    for expect in ("no-host-callback", "gather-only-levels",
                   "static-shapes", "kv-donation", "dtype-purity",
                   "sharding-integrity"):
        assert expect in names, names


# -- walker ------------------------------------------------------------------

def test_walker_recurses_with_loop_membership_and_paths():
    def f(x):
        def body(c, _):
            y = lax.cond(c.sum() > 0, lambda v: v * 2, lambda v: v + 1, c)
            return y, None
        out, _ = lax.scan(body, x, None, length=3)
        return out + 1

    sites = list(iter_eqns(jax.make_jaxpr(f)(jnp.ones((4,)))))
    prims = {s.primitive for s in sites}
    assert "scan" in prims and "cond" in prims
    # everything under the scan body is loop-resident; the trailing add
    # at top level is not
    in_scan = [s for s in sites if "scan/" in s.path]
    assert in_scan and all(s.in_loop for s in in_scan)
    top = [s for s in sites if "/" not in s.path]
    assert top and not any(s.in_loop for s in top)
    # paths are eqn-indexed and nest ("3:scan/jaxpr/0:cond/branches/...")
    assert any(s.path.count("/") >= 2 for s in sites)


def test_walker_inherits_named_scopes_into_subjaxprs():
    def f(x):
        with jax.named_scope("quantize_kv"):
            def body(c, _):
                return c * 2.0, None
            y, _ = lax.scan(body, x, None, length=2)
        return y + 1.0

    sites = list(iter_eqns(jax.make_jaxpr(f)(jnp.ones((4,)))))
    inner = [s for s in sites if "scan/" in s.path]
    assert inner and all("quantize_kv" in s.scopes for s in inner)
    top_add = [s for s in sites if s.primitive == "add"]
    assert top_add and not any("quantize_kv" in s.scopes
                               for s in top_add)


# -- positive controls: each rule fires on a violating program ---------------

def test_control_no_host_callback_fires():
    def f(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)
    found = analysis.find_violations(f, jnp.ones((4,), jnp.float32),
                                     rules=("no-host-callback",))
    assert found and found[0].primitive == "pure_callback"
    assert "pure_callback" in found[0].path


def test_control_gather_only_levels_fires_inside_scan_only():
    def scatter_in_loop(x):
        def body(c, _):
            return c.at[0].set(c.sum()), None
        y, _ = lax.scan(body, x, None, length=3)
        return y

    found = analysis.find_violations(scatter_in_loop, jnp.ones((4,)),
                                     rules=("gather-only-levels",))
    assert found and found[0].rule == "gather-only-levels"
    assert found[0].primitive.startswith("scatter")
    assert "scan/" in found[0].path

    # the same scatter OUTSIDE any loop is the legal direct dispatch
    assert analysis.find_violations(
        lambda x: x.at[0].set(x.sum()), jnp.ones((4,)),
        rules=("gather-only-levels",)) == []


def test_control_static_shapes_fires_on_while():
    def f(x):
        return lax.while_loop(lambda c: c[0] < 10,
                              lambda c: (c[0] + 1, c[1] * 2.0),
                              (jnp.int32(0), x))
    found = analysis.find_violations(f, jnp.ones((4,)),
                                     rules=("static-shapes",))
    assert found and found[0].primitive == "while"
    # fori_loop with static bounds lowers to scan: clean
    assert analysis.find_violations(
        lambda x: lax.fori_loop(0, 4, lambda i, c: c * 2.0, x),
        jnp.ones((4,)), rules=("static-shapes",)) == []


def test_control_kv_donation_fires_when_lowering_drops_donation():
    def f(p, cache):
        return cache + p

    x = jnp.zeros((64,), jnp.float32)
    undonated = jax.jit(f, keep_unused=True).lower(x, x).as_text()
    prog = R.LintProgram(name="decode", rules=("kv-donation",),
                         lowered_text=undonated,
                         donate_expect={"kv-cache": (1, 2)})
    found = R.run_rules(prog)
    assert found and found[0].rule == "kv-donation"
    assert "NOT aliased" in found[0].message

    donated = jax.jit(f, donate_argnums=(1,),
                      keep_unused=True).lower(x, x).as_text()
    prog.lowered_text = donated
    assert R.run_rules(prog) == []


def test_aliased_args_reads_both_donation_markers():
    # single-device lowering: input aliased to a concrete output
    single = ('func.func public @main(%arg0: tensor<4xf32>, '
              '%arg1: tensor<4xf32> {tf.aliasing_output = 0 : i32}) {')
    assert aliased_args(single) == {1}
    # mesh lowering: pairing deferred to the compiler
    meshed = ('func.func public @main(%arg0: tensor<4xf32> '
              '{jax.buffer_donor = true, mhlo.sharding = "..."}, '
              '%arg1: tensor<4xf32>) {')
    assert aliased_args(meshed) == {0}
    assert aliased_args("func.func @main(%arg0: tensor<4xf32>) {") == set()


def test_control_dtype_purity_fires_on_bf16_in_quantize_scope():
    def bad(x):
        with jax.named_scope("quantize_kv"):
            scale = (jnp.max(jnp.abs(x), -1, keepdims=True)
                     .astype(jnp.bfloat16) / 127.0)
        return x / scale.astype(jnp.float32)

    found = analysis.find_violations(bad, jnp.ones((4, 8), jnp.float32),
                                     rules=("dtype-purity",))
    assert found and "quantize_kv" in found[0].message

    # the clean shape: cast INTO f32 first (attention._quantize_kv) —
    # the convert's *output* is f32, so bf16 inputs do not trip the rule
    def good(x):
        with jax.named_scope("quantize_kv"):
            x32 = x.astype(jnp.float32)
            return x32 / (jnp.max(jnp.abs(x32), -1, keepdims=True) / 127.)
    assert analysis.find_violations(
        good, jnp.ones((4, 8), jnp.bfloat16),
        rules=("dtype-purity",)) == []

    # bf16 arithmetic OUTSIDE a quantize scope is fine (model math)
    assert analysis.find_violations(
        lambda x: x * 2, jnp.ones((4,), jnp.bfloat16),
        rules=("dtype-purity",)) == []


def test_control_dtype_purity_fires_on_f64_anywhere():
    with jax.experimental.enable_x64():
        jaxpr = jax.make_jaxpr(lambda x: x * 2.0)(
            jnp.ones((4,), jnp.float64))
    found = analysis.find_violations(jaxpr, rules=("dtype-purity",))
    assert found and "float64" in found[0].message


class _MockSharding:
    def __init__(self, replicated):
        self.is_fully_replicated = replicated


class _MockLeaf:
    def __init__(self, shape, replicated, itemsize=4):
        self.shape = shape
        self.nbytes = int(np.prod(shape)) * itemsize
        self.sharding = _MockSharding(replicated)


class _MockMesh:
    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_control_sharding_integrity_fires_on_replicated_cache():
    prog = R.LintProgram(
        name="decode", rules=("sharding-integrity",),
        mesh=_MockMesh(data=4),
        arrays={"kv-cache": {"k": _MockLeaf((4, 16, 64), replicated=True),
                             "v": _MockLeaf((4, 16, 64),
                                            replicated=False)}})
    found = R.run_rules(prog)
    assert len(found) == 1 and found[0].rule == "sharding-integrity"
    assert "kv-cache" in found[0].path and "'k'" in found[0].path
    assert "fully replicated" in found[0].message

    # scalars/small arrays (step counters) are exempt by min_bytes
    prog.arrays = {"kv-cache": {"step": _MockLeaf((4,), replicated=True)}}
    assert R.run_rules(prog) == []

    # a 1-device mesh has nothing to shard over
    prog.arrays = {"kv-cache": {"k": _MockLeaf((4, 16, 64), True)}}
    prog.mesh = _MockMesh(data=1)
    assert R.run_rules(prog) == []


# -- public surface ----------------------------------------------------------

def test_assert_clean_passes_and_raises_with_location():
    analysis.assert_clean(lambda x: x * 2, jnp.ones((4,)))
    def dirty(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)
    with pytest.raises(AssertionError, match="no-host-callback") as ei:
        analysis.assert_clean(dirty, jnp.ones((4,), jnp.float32))
    assert "pure_callback" in str(ei.value)   # primitive + path, not
    assert ":" in str(ei.value)               # just "string appeared"


def test_assert_clean_baseline_suppresses():
    def dirty(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)
    found = analysis.find_violations(dirty, jnp.ones((4,), jnp.float32))
    analysis.assert_clean(dirty, jnp.ones((4,), jnp.float32),
                          baseline=tuple(f.key() for f in found))


def test_find_violations_rejects_args_with_ready_jaxpr():
    jaxpr = jax.make_jaxpr(lambda x: x + 1)(jnp.ones((4,)))
    with pytest.raises(TypeError, match="ClosedJaxpr"):
        analysis.find_violations(jaxpr, jnp.ones((4,)))


def test_baseline_roundtrip(tmp_path):
    def dirty(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)
    found = analysis.find_violations(dirty, jnp.ones((4,), jnp.float32))
    p = tmp_path / "lint_baseline.txt"
    n = save_baseline(str(p), found)
    assert n == len({f.key() for f in found})
    loaded = load_baseline(str(p))
    new, suppressed = split_baselined(found, loaded)
    assert new == [] and suppressed == found
    # comments and blanks are ignored; unknown path is loud
    p.write_text("# comment\n\n" + found[0].key() + "\n")
    assert load_baseline(str(p)) == {found[0].key()}
    with pytest.raises(FileNotFoundError):
        load_baseline(str(tmp_path / "missing.txt"))
    assert load_baseline(None) == frozenset()


def test_run_rules_honors_exemption_and_skips_missing_evidence():
    jaxpr = jax.make_jaxpr(lambda x: jax.pure_callback(
        np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x))(
            jnp.ones((4,), jnp.float32))
    prog = R.LintProgram(
        name="decode", rules=("no-host-callback", "kv-donation"),
        jaxpr=jaxpr)                       # no lowered_text
    # kv-donation silently skipped (no evidence); callback found
    assert [f.rule for f in R.run_rules(prog)] == ["no-host-callback"]
    # the host-oracle backend's exemption silences its one legal callback
    assert R.run_rules(prog,
                       exempt=frozenset({"no-host-callback"})) == []


def test_engine_backend_declares_callback_exemption():
    from repro.core.backend import get_backend
    assert "no-host-callback" in get_backend("engine").lint_exempt
    assert get_backend("engine_jit").lint_exempt == frozenset()
    profile = get_backend("engine").lint_profile()
    assert profile["no-host-callback"] is False
    assert profile["kv-donation"] is True


def test_lint_backend_end_to_end_clean():
    """The acceptance smoke: a real registered backend's whole program
    set (prefill, donated decode, paged decode + its post-hot-swap twin,
    the two fast-path programs, forest) lints clean."""
    from repro.analysis.programs import lint_backend
    progs, findings = lint_backend("engine_jit", n_layers=1, batch=2)
    assert [p.name for p in progs] == ["prefill", "decode",
                                      "paged-decode",
                                      "paged-decode-swapped",
                                      "paged-attention",
                                      "prefill-bucketed", "forest"]
    assert findings == [], [f.format() for f in findings]


def test_lint_cli_single_backend(capsys):
    """`python -m repro.analysis.lint --backend int_dot` exits 0 and
    reports per-backend status lines."""
    from repro.analysis.lint import main
    rc = main(["--backend", "int_dot", "--batch", "2"])
    out = capsys.readouterr().out
    assert rc == 0 and "int_dot" in out and "clean" in out


def test_lint_cli_list_rules(capsys):
    from repro.analysis.lint import main
    assert main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for name in R.list_rules():
        assert name in out


# -- ISSUE 10 satellites: custom-derivative recursion + baseline pruning -----

def test_control_callback_found_under_custom_jvp():
    """A pure_callback cannot hide behind jax.custom_jvp: the walker
    enters the primal call_jaxpr of custom_jvp_call."""
    @jax.custom_jvp
    def f(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)

    @f.defjvp
    def f_jvp(primals, tangents):
        (x,), (dx,) = primals, tangents
        return f(x), jnp.cos(x) * dx

    found = analysis.find_violations(
        lambda x: f(x) * 2.0, jnp.ones((4,), jnp.float32),
        rules=("no-host-callback",))
    assert found and found[0].primitive == "pure_callback"
    assert "custom_jvp_call" in found[0].path


def test_control_callback_found_under_custom_vjp():
    @jax.custom_vjp
    def f(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)

    f.defvjp(lambda x: (f(x), x), lambda res, g: (g * jnp.cos(res),))

    found = analysis.find_violations(
        lambda x: f(x) + 1.0, jnp.ones((4,), jnp.float32),
        rules=("no-host-callback",))
    assert found and found[0].primitive == "pure_callback"
    assert "custom_vjp_call" in found[0].path


def test_baseline_stale_keys_and_prune():
    from repro.analysis.baseline import stale_keys

    def dirty(x):
        return jax.pure_callback(
            np.sin, jax.ShapeDtypeStruct((4,), jnp.float32), x)
    found = analysis.find_violations(dirty, jnp.ones((4,), jnp.float32))
    live = found[0].key()
    dead = "no-host-callback::engine::retired-program::pure_callback"
    assert stale_keys({live, dead}, found) == [dead]
    assert stale_keys({live}, found) == []
    assert stale_keys(set(), found) == []


def test_lint_cli_prune_baseline(tmp_path, capsys):
    """`lint --prune-baseline` reports stale allowlist entries and, with
    --write-baseline, rewrites the file without them."""
    from repro.analysis.lint import main
    dead = "no-host-callback::int_dot::retired-program::pure_callback"
    p = tmp_path / "baseline.txt"
    p.write_text(dead + "\n")
    rc = main(["--backend", "int_dot", "--batch", "2",
               "--baseline", str(p), "--prune-baseline"])
    out = capsys.readouterr().out
    assert rc == 0
    assert f"stale: {dead}" in out and "1 stale entry" in out
    rc = main(["--backend", "int_dot", "--batch", "2",
               "--baseline", str(p), "--prune-baseline",
               "--write-baseline", str(p)])
    assert rc == 0
    assert dead not in p.read_text()


def test_lint_cli_plans_and_budgets_sections(tmp_path, capsys):
    """--plans/--budgets merge into the findings stream and the JSON
    report gains their sections."""
    import json as _json

    from repro.analysis.lint import main
    from repro.core.plancache import PlanCache, set_default_cache
    prev = set_default_cache(PlanCache(capacity=64))
    try:
        out_json = tmp_path / "lint.json"
        rc = main(["--backend", "engine_jit", "--plans", "--budgets",
                   "--json", str(out_json)])
    finally:
        set_default_cache(prev)
    out = capsys.readouterr().out
    assert rc == 0
    assert "[planlint]" in out and "[costcheck]" in out
    doc = _json.loads(out_json.read_text())
    assert doc["plans"] and doc["plans"][0]["backend"] == "engine_jit"
    assert any(r.get("ok") for r in doc["budgets"])
