"""Per-architecture smoke tests (reduced configs, one train step + serve)
and a prefill↔decode cache-consistency check."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_reduced
from repro.models.model import Model


def _batch(cfg, b=2, s=64, key=0):
    k = jax.random.PRNGKey(key)
    batch = {
        "tokens": jax.random.randint(k, (b, s), 0, cfg.vocab, jnp.int32),
        "labels": jax.random.randint(k, (b, s), 0, cfg.vocab, jnp.int32),
    }
    if cfg.n_context_tokens or cfg.is_encdec:
        batch["context"] = jax.random.normal(
            k, (b, cfg.n_context_tokens, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke(arch):
    """Reduced config: one fwd/bwd step on CPU, finite loss & grads,
    correct logits shapes in serve mode."""
    cfg = get_reduced(arch)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()

    logits, caches = m.prefill(params, batch, max_len=96)
    assert logits.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, _ = m.decode_step(params, caches, tok, jnp.int32(64))
    assert logits2.shape == (2, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_constructs(arch):
    """Full-size configs build abstract params with sane byte counts."""
    cfg = get_config(arch)
    m = Model(cfg)
    shapes = jax.eval_shape(lambda: m.init(jax.random.PRNGKey(0)))
    total = sum(np.prod(l.shape) for l in jax.tree.leaves(shapes))
    assert total > 1e6            # everything is at least a million params


@pytest.mark.parametrize("arch", ["smollm_135m", "recurrentgemma_9b",
                                  "xlstm_125m", "whisper_tiny",
                                  "chatglm3_6b"])
def test_prefill_decode_consistency(arch):
    """logits(prefill(s)) == logits(prefill(s-k) + k decode steps):
    validates KV caches, rolling windows, RoPE offsets, recurrent states."""
    cfg = get_reduced(arch).replace(dtype=jnp.float32)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s, split = 2, 32, 24
    batch = _batch(cfg, b=b, s=s, key=3)
    full_logits, _ = m.prefill(params, batch, max_len=s + 8)

    part = {k: (v[:, :split] if k != "context" else v)
            for k, v in batch.items()}
    logits, caches = m.prefill(params, part, max_len=s + 8)
    for i in range(split, s):
        tok = batch["tokens"][:, i:i + 1]
        logits, caches = m.decode_step(params, caches, tok, jnp.int32(i))
    np.testing.assert_allclose(np.asarray(logits), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_moe_routing_mass_conserved():
    """MoE gates renormalise to 1 over the top-k."""
    cfg = get_reduced("moonshot_v1_16b_a3b")
    from repro.models import blocks as B
    p = B.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    y = B.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()


def test_local_window_masks_past():
    """With a local window, tokens beyond the window don't affect logits."""
    cfg = get_reduced("recurrentgemma_9b").replace(
        dtype=jnp.float32, block_pattern=("attn",), block_tail=(),
        n_layers=2, local_window=8)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b1 = _batch(cfg, b=1, s=32, key=1)
    b2 = {k: v.copy() for k, v in b1.items()}
    b2["tokens"] = b2["tokens"].at[:, 0].set(
        (b2["tokens"][:, 0] + 1) % cfg.vocab)   # differs outside the window
    l1, _ = m.prefill(params, b1, max_len=40)
    l2, _ = m.prefill(params, b2, max_len=40)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
