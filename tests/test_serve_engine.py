"""Continuous-batching serve engine (ISSUE 6): paged KV pool, prefix trie,
request scheduler.

The acceptance property pinned here: every request's token stream out of
``ServeEngine`` — packed decode slots, staggered arrivals, pages shared
through the prefix trie — is **bit-identical** to running that request
alone through ``greedy_generate`` with the same ``max_len``, for every
device-resident backend in the registry. Around it: unit tests for the
page allocator and the prefix trie (LRU leaf-only eviction, refcount
pinning), the exact-pool compute-skip counters (shared prefixes re-prefill
zero shared pages), KV8 parity (shared bytes, recomputed activations),
scheduler admission/eviction/stall behaviour, and the
``serve_engine_bench`` JSON contract (``serve_engine.tokens_per_s``).
"""
import os
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core.backend import get_backend, list_backends
from repro.launch.specs import serve_config
from repro.models.model import Model
from repro.serve import (NULL_PAGE, PageAllocator, PrefixTrie, ServeEngine,
                         bucket)
from repro.train.serve_step import greedy_generate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEVICE_BACKENDS = [n for n in list_backends()
                   if get_backend(n).device_resident
                   and get_backend(n).cpu_ok]


@pytest.fixture
def cache():
    """Fresh process-default plan cache per test; restores the previous."""
    from repro.core.plancache import PlanCache, set_default_cache
    c = PlanCache(capacity=64)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


@pytest.fixture(scope="module")
def fp_cell():
    """Exact-pool (KV16) cell: the compute-skip prefix path."""
    cfg = get_reduced("smollm_135m").replace(n_layers=2)
    model = Model(cfg)
    return model, model.init(jax.random.PRNGKey(0))


def _prompts(cfg, plen=8, n=4, seed=7):
    """n prompts; evens replay prompt 0, odds share its first half."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab, size=plen).tolist()
    return [list(base) if i % 2 == 0 else
            base[:plen // 2]
            + rng.integers(0, cfg.vocab, size=plen - plen // 2).tolist()
            for i in range(n)]


def _reference(model, params, prompt, max_len, n_new):
    """The request alone through today's one-shot path, same max_len."""
    batch = {"tokens": jnp.asarray([prompt], jnp.int32)}
    return np.asarray(greedy_generate(model, params, batch,
                                      max_len=max_len, n_steps=n_new))[0]


# -- page allocator ----------------------------------------------------------

def test_allocator_basics():
    a = PageAllocator(5)                  # pages 1..4; 0 is the null page
    got = [a.alloc() for _ in range(4)]
    assert sorted(got) == [1, 2, 3, 4] and NULL_PAGE not in got
    assert a.alloc() is None              # exhausted, no exception
    assert a.free_count == 0 and a.used == 4
    assert a.decref(got[0]) is True       # refcount 1 -> freed
    assert a.free_count == 1
    pid = a.alloc()
    assert pid == got[0]                  # freed page comes back
    s = a.stats()
    assert s["allocated"] == 5 and s["freed"] == 1 and s["peak_used"] == 4


def test_allocator_refcounts():
    a = PageAllocator(4)
    pid = a.alloc()
    a.incref(pid)                         # a second holder (the trie, say)
    assert a.refcount(pid) == 2
    assert a.decref(pid) is False         # still held
    assert a.decref(pid) is True          # last ref -> freed
    assert a.free_count == 3
    with pytest.raises(ValueError):
        a.decref(pid)                     # double-free is loud


# -- prefix trie -------------------------------------------------------------

def test_trie_match_insert():
    a = PageAllocator(16)
    t = PrefixTrie(page_size=4)
    prompt = list(range(40, 49))          # 9 tokens: 2 full pages + 1 tail
    pages = [a.alloc() for _ in range(3)]
    added = t.insert(prompt, pages, a)
    assert added == 2 and len(t) == 2     # only fully-covered pages indexed
    assert a.refcount(pages[0]) == 2      # trie pins what it indexes
    assert a.refcount(pages[2]) == 1      # the tail page is not indexed
    # full-prefix hit, capped so the suffix keeps >= 1 token
    assert t.match(prompt, max_pages=2) == pages[:2]
    assert t.match(prompt, max_pages=1) == pages[:1]
    # divergence inside page 2: only page 1 shared
    other = prompt[:4] + [99] * 5
    assert t.match(other, max_pages=2) == pages[:1]
    assert t.match([99] * 8, max_pages=2) == []
    s = t.stats()
    assert s["pages_inserted"] == 2 and s["pages_matched"] == 4


def test_trie_evict_leaf_lru_only():
    a = PageAllocator(16)
    t = PrefixTrie(page_size=2)
    p1 = [1, 2, 3, 4]
    p2 = [1, 2, 7, 8]
    t.insert(p1, [a.alloc(), a.alloc()], a)
    t.insert(p2, [t.match(p2, max_pages=1)[0], a.alloc()], a)
    # drop the request refs: pages now live only in the trie
    for pid in range(1, 4):
        a.decref(pid)
    t.match(p1, max_pages=2)              # touch p1's leaf -> p2's is LRU
    assert t.evict(a, 1) == 1
    assert t.match(p2, max_pages=2) == [1]    # p2's leaf gone, root kept
    assert t.match(p1, max_pages=2) == [1, 2]  # p1 intact (leaf-only LRU)
    # the shared root page is only evictable once its children are gone
    assert t.evict(a, 2) == 2
    assert len(t) == 0 and a.free_count == a.n_pages - 1


def test_trie_never_evicts_held_pages():
    a = PageAllocator(8)
    t = PrefixTrie(page_size=2)
    t.insert([5, 6], [a.alloc()], a)      # refcount 2: request + trie
    assert t.evict(a, 1) == 0             # pinned -> not evictable
    a.decref(1)
    assert t.evict(a, 1) == 1


# -- engine construction / submission validation -----------------------------

def test_engine_validation(fp_cell):
    model, params = fp_cell
    with pytest.raises(ValueError, match="multiple of page_size"):
        ServeEngine(model, params, max_len=10, page_size=4)
    with pytest.raises(ValueError, match="n_slots"):
        ServeEngine(model, params, n_slots=0, max_len=8, page_size=4)
    eng = ServeEngine(model, params, n_slots=2, max_len=8, page_size=4)
    with pytest.raises(ValueError, match="empty"):
        eng.submit([], 2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        eng.submit([1, 2], 0)
    with pytest.raises(ValueError, match="max_len"):
        eng.submit([1, 2, 3, 4], 6)       # 4 + 6 - 1 > 8


# -- exact pool: shared prefixes skip prefill compute ------------------------

def test_prefix_reuse_skips_shared_compute(fp_cell):
    """KV16: the second request over the same prompt re-prefills ZERO
    shared pages — compute starts at the shared boundary and only the
    non-shared tail is written."""
    model, params = fp_cell
    cfg = model.cfg
    assert cfg.kv_cache_bits != 8
    eng = ServeEngine(model, params, n_slots=2, max_len=16, page_size=4)
    plen, gen = 9, 3                      # 2 full pages + 1 tail page
    prompts = _prompts(cfg, plen=plen, n=3)
    for p in prompts:
        eng.submit(p, gen)
    done = eng.run()
    assert len(done) == 3
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].shared_pages == 0
    assert by_rid[0].prefill_computed == plen
    # rid 2 replays prompt 0 entirely: both full pages shared, compute
    # covers only the tail (9 - 8 = 1 position)
    assert by_rid[2].shared_pages == 2
    assert by_rid[2].prefill_computed == plen - 8
    # rid 1 shares the first half (page 0 only)
    assert by_rid[1].shared_pages == 1
    assert by_rid[1].prefill_computed == plen - 4
    c = eng.counters
    assert c["prefix_hits"] == 2 and c["pages_shared"] == 3
    assert c["prefill_skipped"] == 12     # 2*4 + 1*4 positions never ran
    # written rows never overlap a shared page
    assert c["prefill_written"] == 3 * plen - c["prefill_skipped"]
    # identical prompts -> identical greedy continuations
    assert by_rid[0].tokens == by_rid[2].tokens
    # and the engine's tokens match the one-shot path
    for r in done:
        ref = _reference(model, params, list(r.prompt), 16, gen)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)


def test_staggered_equals_batch_submit(fp_cell):
    """Scheduling is invisible in the tokens: staggered arrivals through
    busy slots produce the same streams as submit-all-then-run."""
    model, params = fp_cell
    prompts = _prompts(model.cfg, plen=6, n=4, seed=11)
    eng_a = ServeEngine(model, params, n_slots=2, max_len=12, page_size=4)
    for p in prompts:
        eng_a.submit(p, 4)
    toks_a = {r.rid: r.tokens for r in eng_a.run()}

    eng_b = ServeEngine(model, params, n_slots=2, max_len=12, page_size=4)
    submitted = 0
    while submitted < len(prompts) or eng_b.queue or eng_b.active:
        if submitted < len(prompts):
            eng_b.submit(prompts[submitted], 4)
            submitted += 1
        eng_b.step()
    toks_b = {r.rid: r.tokens for r in eng_b.finished}
    assert toks_a == toks_b


# -- bit-identity across backends (the acceptance property) ------------------

@pytest.mark.parametrize("kernel", [False, True],
                         ids=["gather", "paged-kernel"])
@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_tokens_bit_identical_per_backend(backend, kernel, cache):
    """Every device-resident backend: ServeEngine tokens == the request
    alone through greedy_generate, under the full serving config (W4A8 +
    KV8 + quantized attention), with prefix sharing active — on both the
    gather-decode oracle and the Pallas live-page kernel path."""
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend=backend)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b = get_backend(backend)
    if b.needs_plan:
        model.precompile_plans(params)
        params = model.attach_device_plans(params)
    max_len, gen = 12, 4
    prompts = _prompts(cfg, plen=6, n=3, seed=5)
    eng = ServeEngine(model, params, n_slots=2, max_len=max_len,
                      page_size=4, paged_kernel=kernel)
    for p in prompts:
        eng.submit(p, gen)
    done = eng.run()
    assert len(done) == len(prompts)
    assert eng.counters["pages_shared"] > 0    # sharing actually engaged
    for r in done:
        ref = _reference(model, params, list(r.prompt), max_len, gen)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref,
                                      err_msg=f"rid={r.rid} {backend}")


def test_kv8_shares_bytes_recomputes_activations(cache):
    """KV8 pools share pages (per-token quantization is deterministic) but
    never skip prefill compute — the counters must show both."""
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend="int_dot")
    assert cfg.kv_cache_bits == 8
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    plen = 8
    prompts = _prompts(cfg, plen=plen, n=3, seed=9)
    eng = ServeEngine(model, params, n_slots=2, max_len=16, page_size=4)
    for p in prompts:
        eng.submit(p, 3)
    done = eng.run()
    c = eng.counters
    # match is capped at (8-1)//4 = 1 page, so both sharers take one
    assert c["pages_shared"] == 2
    assert c["prefill_skipped"] == 8           # bytes skipped, shared rows
    assert c["prefill_computed"] == 3 * plen   # ... but compute never is
    for r in done:
        ref = _reference(model, params, list(r.prompt), 16, 3)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)


# -- scheduler ---------------------------------------------------------------

def test_more_requests_than_slots(fp_cell):
    """5 requests through 2 slots: all finish, slots turn over, the page
    pool returns to its idle level (trie-held pages only)."""
    model, params = fp_cell
    prompts = _prompts(model.cfg, plen=5, n=5, seed=3)
    eng = ServeEngine(model, params, n_slots=2, max_len=8, page_size=4)
    rids = [eng.submit(p, 4) for p in prompts]
    done = eng.run()
    assert sorted(r.rid for r in done) == rids
    assert eng.counters["completed"] == 5
    assert not eng.active and not eng.queue
    assert all(len(r.tokens) == 4 for r in done)
    # finished requests released their pages; only the trie still holds
    assert eng.alloc.used == eng.trie.stats()["pages"]
    for r in done:
        ref = _reference(model, params, list(r.prompt), 8, 4)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)


def test_lazy_page_growth_across_boundary(fp_cell):
    """Decode allocates pages lazily when a request's length crosses a
    page boundary mid-generation."""
    model, params = fp_cell
    prompt = _prompts(model.cfg, plen=5, n=1, seed=13)[0]
    eng = ServeEngine(model, params, n_slots=1, max_len=16, page_size=4)
    eng.submit(prompt, 8)                 # rows 5..11: pages 2 and 3 lazily
    (req,) = eng.run()
    assert len(req.page_ids) == 3         # ceil(12 / 4): grown from 2
    ref = _reference(model, params, prompt, 16, 8)
    np.testing.assert_array_equal(np.asarray(req.tokens), ref)


def test_eos_stops_early(fp_cell):
    model, params = fp_cell
    prompt = _prompts(model.cfg, plen=5, n=1, seed=17)[0]
    eng = ServeEngine(model, params, n_slots=1, max_len=16, page_size=4)
    ref = _reference(model, params, prompt, 16, 6).tolist()
    eos = ref[2]
    eng.submit(prompt, 6, eos_id=eos)
    (req,) = eng.run()
    # stops AT the first eos occurrence (which may be earlier than idx 2
    # if the greedy stream happens to repeat the token)
    assert req.tokens == ref[:ref.index(eos) + 1]


def test_run_stall_raises(fp_cell):
    """A request that can never be admitted (pool smaller than its prompt)
    stalls loudly instead of spinning forever."""
    model, params = fp_cell
    eng = ServeEngine(model, params, n_slots=1, max_len=8, page_size=4,
                      n_pages=2)          # 1 usable page, prompt needs 2
    eng.submit(list(range(5)), 2)
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()


def test_requires_paged_support(fp_cell):
    _, params = fp_cell
    cfg = get_reduced("recurrentgemma_9b")     # non-attn blocks
    with pytest.raises(NotImplementedError, match="paged"):
        ServeEngine(Model(cfg), params, max_len=8, page_size=4)


# -- the fast path: bucketed batched prefill + Pallas live-page decode -------

def test_bucket_unit():
    assert [bucket(n, 64) for n in (1, 2, 3, 4, 5, 8, 9, 33)] == \
        [1, 2, 4, 4, 8, 8, 16, 64]
    assert bucket(100, 64) == 64          # clamped to the cap
    with pytest.raises(ValueError):
        bucket(0, 64)


def _fresh_prompts(cfg, lens, seed=21):
    """Distinct random prompts (no accidental prefix sharing)."""
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab, size=n).tolist() for n in lens]


def test_bucket_boundary_identity(fp_cell):
    """Prompt lengths at bucket edges, edge+-1 and exact page_size
    multiples stay bit-identical to the per-request oracle through the
    bucketed batched prefill, and the jit specializations are bounded by
    the bucket set, not the length set."""
    model, params = fp_cell
    max_len, gen, ps = 32, 3, 4
    # buckets 4 / 8 / 16 / 32: each edge, edge+-1, and the page_size
    # multiples 4, 8, 12, 16 (12 is a multiple that is NOT a power of two)
    lens = [3, 4, 5, 7, 8, 9, 12, 15, 16, 17]
    prompts = _fresh_prompts(model.cfg, lens)
    eng = ServeEngine(model, params, n_slots=len(lens), max_len=max_len,
                      page_size=ps)
    for p in prompts:
        eng.submit(p, gen)
    done = eng.run()
    assert len(done) == len(lens)
    for r in done:
        ref = _reference(model, params, list(r.prompt), max_len, gen)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref,
                                      err_msg=f"plen={len(r.prompt)}")
    # one admission wave: the 10 lengths collapse into 4 suffix buckets
    # (4, 8, 16, 32), one batched call and one trace each
    c = eng.counters
    assert c["prefill_batched_calls"] == 4
    assert c["prefill_batched_rows"] == len(lens)
    assert eng.stats()["prefill_traces"] == 4
    assert c["bucket_hits"] == 0          # every key was new
    # a second wave re-using a seen (batch, bucket) key is a bucket hit
    # and must not add a specialization
    for p in _fresh_prompts(model.cfg, [3, 4], seed=22):
        eng.submit(p, gen)
    done2 = eng.run()
    for r in done2:
        ref = _reference(model, params, list(r.prompt), max_len, gen)
        np.testing.assert_array_equal(np.asarray(r.tokens), ref)
    assert eng.counters["bucket_hits"] >= 1
    assert eng.stats()["prefill_traces"] == 4


def test_bucketed_vs_per_request_prefill_identical(fp_cell):
    """bucket_prefill on/off is invisible in the tokens (same engine,
    same prompts, prefix sharing active)."""
    model, params = fp_cell
    prompts = _prompts(model.cfg, plen=7, n=4, seed=19)
    toks = {}
    for on in (True, False):
        eng = ServeEngine(model, params, n_slots=4, max_len=16,
                          page_size=4, bucket_prefill=on)
        for p in prompts:
            eng.submit(p, 4)
        toks[on] = {r.rid: r.tokens for r in eng.run()}
        calls = eng.counters["prefill_batched_calls"]
        assert (calls > 0) if on else (calls == 0)
    assert toks[True] == toks[False]


@pytest.mark.parametrize("page_size", [2, 4, 8])
def test_paged_kernel_vs_gather_parity(fp_cell, page_size):
    """decode_step_paged(kernel=True) == the gather oracle, bit for bit
    (logits and written pool bytes), over slots with ragged live-page
    counts and random pool contents."""
    model, params = fp_cell
    n_slots, max_len = 4, 32
    pps = max_len // page_size
    pool = model.init_page_pool(n_slots * pps + 1, page_size)
    leaves, treedef = jax.tree_util.tree_flatten(pool)
    key = jax.random.PRNGKey(3)
    pool = jax.tree_util.tree_unflatten(treedef, [
        jax.random.normal(jax.random.fold_in(key, i), leaf.shape,
                          jnp.float32).astype(leaf.dtype)
        for i, leaf in enumerate(leaves)])
    # ragged: 1, 1, 2 and 3 live pages across the four slots
    steps = [0, 1, page_size, 3 * page_size - 1]
    table = np.zeros((n_slots, pps), np.int32)
    nxt = 1
    for s in range(n_slots):
        for p in range(steps[s] // page_size + 1):
            table[s, p], nxt = nxt, nxt + 1
    tok = jnp.asarray([[5], [11], [23], [42]], jnp.int32)
    fn = jax.jit(model.decode_step_paged, static_argnames=("kernel",))
    args = (params, pool, tok, jnp.asarray(table),
            jnp.asarray(steps, jnp.int32))
    lg, pool_g = fn(*args, kernel=False)
    lk, pool_k = fn(*args, kernel=True)
    np.testing.assert_array_equal(np.asarray(lg), np.asarray(lk))
    for a, b in zip(jax.tree_util.tree_leaves(pool_g),
                    jax.tree_util.tree_leaves(pool_k)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_paged_kernel_engine_ragged_identity(fp_cell):
    """Kernel-path engine over slots with ragged live-page counts: equal
    to the per-request oracle AND to the gather-path engine, token for
    token, with decode crossing page boundaries mid-generation."""
    model, params = fp_cell
    max_len, gen = 32, 6
    prompts = _fresh_prompts(model.cfg, [3, 6, 11, 20], seed=23)
    toks = {}
    for kern in (False, True):
        eng = ServeEngine(model, params, n_slots=4, max_len=max_len,
                          page_size=4, paged_kernel=kern)
        for p in prompts:
            eng.submit(p, gen)
        toks[kern] = {r.rid: r.tokens for r in eng.run()}
        assert eng.stats()["decode_traces"] == 1   # one shape either way
        for r in eng.finished:
            ref = _reference(model, params, list(r.prompt), max_len, gen)
            np.testing.assert_array_equal(
                np.asarray(r.tokens), ref,
                err_msg=f"kernel={kern} plen={len(r.prompt)}")
    assert toks[False] == toks[True]


# -- bench contract ----------------------------------------------------------

def test_serve_engine_bench_emits_tokens_per_s(cache):
    """The BENCH_engine.json ``serve_engine`` entry: throughput series +
    prefix counters (the CI perf-trajectory contract)."""
    sys.path.insert(0, ROOT)
    try:
        from benchmarks.bench_kernel import serve_engine_bench
    finally:
        sys.path.remove(ROOT)
    r = serve_engine_bench(smoke=True)
    assert r["tokens_per_s"] > 0
    assert r["total_tokens"] == r["n_requests"] * r["gen"]
    assert r["series"] and r["series"][-1]["tokens"] == r["total_tokens"]
    assert len(r["ttft_s"]) == r["n_requests"]
    assert r["counters"]["pages_shared"] > 0
    assert r["counters"]["completed"] == r["n_requests"]
