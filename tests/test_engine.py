"""Differential tests for the batched multi-tile transitive engine.

The testing pyramid (docs/TESTING.md): plain ``W.astype(i64) @ X`` is the
ground truth; core/transitive_ref.py is the row-at-a-time oracle; the
batched engine, the Pallas kernel (interpret mode) and the quant integer
path must all agree with both, bit-exactly, across widths and adversarial
weight patterns.
"""
import numpy as np
import pytest

from repro.core.engine import BatchedTransitiveEngine
from repro.core.transitive_ref import transitive_gemm_ref


def _adversarial_weights(pattern: str, n: int, k: int, bits: int,
                         rng) -> np.ndarray:
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if pattern == "random":
        return rng.integers(lo, hi + 1, size=(n, k))
    if pattern == "zeros":
        return np.zeros((n, k), dtype=np.int64)
    if pattern == "ones":
        return np.ones((n, k), dtype=np.int64)
    if pattern == "neg_ones":                 # all bit planes set (2's compl.)
        return np.full((n, k), -1, dtype=np.int64)
    if pattern == "single_row":
        w = np.zeros((n, k), dtype=np.int64)
        w[0] = rng.integers(lo, hi + 1, size=k)
        return w
    if pattern == "outlier_heavy":
        # very few, very dense TransRows per tile → present nodes sit far
        # (distance >= 4) from any present prefix → scoreboard outliers
        w = np.where(rng.random((n, k)) < 0.9, hi, lo)
        return w
    raise AssertionError(pattern)


PATTERNS = ["random", "zeros", "ones", "neg_ones", "single_row",
            "outlier_heavy"]


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t", [4, 8])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_engine_vs_ref_vs_int64(bits, t, pattern, rng):
    n, k, m = (3, 4 * t, 5) if pattern == "outlier_heavy" else (17, 6 * t, 9)
    w = _adversarial_weights(pattern, n, k, bits, rng)
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    eng = BatchedTransitiveEngine(bits=bits, t=t)
    got = eng(w, x)
    ref = transitive_gemm_ref(w, x, bits, t)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ref, want)


def test_outlier_heavy_actually_exercises_outliers(rng):
    """Guard the adversarial case: it must hit the direct-dispatch path."""
    w = _adversarial_weights("outlier_heavy", 3, 32, 8, rng)
    eng = BatchedTransitiveEngine(bits=8, t=8)
    plan = eng.plan(w)
    assert plan.si.outlier.sum() > 0
    assert plan.direct_tile.size > 0


@pytest.mark.parametrize("bits,t", [(4, 4), (4, 8), (8, 4), (8, 8)])
def test_engine_vs_pallas_interpret(bits, t, rng):
    """engine == Pallas kernel (interpret mode) == int64 GEMM."""
    import jax.numpy as jnp
    from repro.kernels import ops
    n, k, m = 12, 8 * t, 10
    w = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=(n, k))
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    got_eng = BatchedTransitiveEngine(bits=bits, t=t)(w, x)
    # the kernel computes qx (M, K) @ qw (N, K)^T = (engine output)^T
    got_pal = np.asarray(ops.transitive_gemm(
        jnp.asarray(x.T, jnp.int8), jnp.asarray(w, jnp.int8),
        w_bits=bits, t=t)).T
    np.testing.assert_array_equal(got_eng, want)
    np.testing.assert_array_equal(got_pal, want)


@pytest.mark.parametrize("group", [0, 64])
@pytest.mark.parametrize("w_bits", [4, 8])
def test_engine_quant_path_matches_int_dot(group, w_bits):
    """linear_apply path="engine" is bit-exact with the int_dot path."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=w_bits, a_bits=8, group=group)
    p = linear_init(jax.random.PRNGKey(0), 128, 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 128), jnp.float32)
    y_int = linear_apply(p, x, cfg.with_(path="int_dot"))
    y_eng = linear_apply(p, x, cfg.with_(path="engine"))
    np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_eng))


def test_plan_reused_across_activations(rng):
    """One plan, many activations — the paper's offline TransRow packing."""
    w = rng.integers(-8, 8, size=(9, 32))
    eng = BatchedTransitiveEngine(bits=4, t=8)
    plan = eng.plan(w)
    for seed in range(3):
        x = np.random.default_rng(seed).integers(-128, 128, size=(32, 6))
        np.testing.assert_array_equal(
            eng.run(plan, x), w.astype(np.int64) @ x.astype(np.int64))


def test_engine_rejects_bad_shapes(rng):
    eng = BatchedTransitiveEngine(bits=4, t=8)
    with pytest.raises(ValueError):
        eng.plan(rng.integers(-8, 8, size=(4, 12)))     # K % T != 0
    plan = eng.plan(rng.integers(-8, 8, size=(4, 16)))
    with pytest.raises(ValueError):
        eng.run(plan, rng.integers(-8, 8, size=(24, 3)))  # wrong K


# -- kernels/ops.py padding paths (non-divisible M/N/K) ---------------------

@pytest.mark.parametrize("m,n,k", [(13, 10, 40), (1, 3, 8), (129, 65, 264),
                                   (7, 100, 72)])
def test_ops_transitive_gemm_padding(m, n, k, rng):
    """M/N not divisible by block sizes, K not divisible by 256."""
    import jax.numpy as jnp
    from repro.kernels import ops
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    want = qx.astype(np.int64) @ qw.astype(np.int64).T
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=4, t=8))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("batch", [(2, 3), (4,)])
def test_ops_transitive_gemm_padding_batched(batch, rng):
    import jax.numpy as jnp
    from repro.kernels import ops
    k, n = 24, 11
    qx = rng.integers(-128, 128, batch + (k,)).astype(np.int8)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    want = qx.astype(np.int64) @ qw.astype(np.int64).T
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=4, t=8))
    np.testing.assert_array_equal(got, want)


def test_ops_w4a8_gemm_padding(rng):
    """w4a8 wrapper pads M and N; K stays a group multiple."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    m, n, k, g = 13, 21, 128, 64
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    sx = rng.uniform(0.5, 2.0, (m, 1)).astype(np.float32)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    sg = rng.uniform(0.5, 2.0, (n, k // g)).astype(np.float32)
    want = np.asarray(ref.w4a8_matmul_ref(*map(jnp.asarray,
                                               (qx, sx, qw, sg))))
    got = np.asarray(ops.w4a8_gemm(*map(jnp.asarray, (qx, sx, qw, sg)),
                                   group=g))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-2)
