"""Differential tests for the batched multi-tile transitive engine.

The testing pyramid (docs/TESTING.md): plain ``W.astype(i64) @ X`` is the
ground truth; core/transitive_ref.py is the row-at-a-time oracle; the
batched engine, the device-resident plan (``compile_plan`` + ``run_device``
and its Pallas forest kernel), the Pallas LUT kernel (interpret mode) and
the quant integer paths must all agree with both, bit-exactly, across
widths and adversarial weight patterns — under ``jit`` and ``vmap``, with
zero ``pure_callback`` in the device path's lowered jaxpr.
"""
import numpy as np
import pytest

from repro.core.engine import (BatchedTransitiveEngine, ExecutionPlan,
                               compile_plan, compile_plans, run_device,
                               run_device_jit)
from repro.core.transitive_ref import transitive_gemm_ref


def _adversarial_weights(pattern: str, n: int, k: int, bits: int,
                         rng) -> np.ndarray:
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    if pattern == "random":
        return rng.integers(lo, hi + 1, size=(n, k))
    if pattern == "zeros":
        return np.zeros((n, k), dtype=np.int64)
    if pattern == "ones":
        return np.ones((n, k), dtype=np.int64)
    if pattern == "neg_ones":                 # all bit planes set (2's compl.)
        return np.full((n, k), -1, dtype=np.int64)
    if pattern == "single_row":
        w = np.zeros((n, k), dtype=np.int64)
        w[0] = rng.integers(lo, hi + 1, size=k)
        return w
    if pattern == "outlier_heavy":
        # very few, very dense TransRows per tile → present nodes sit far
        # (distance >= 4) from any present prefix → scoreboard outliers
        w = np.where(rng.random((n, k)) < 0.9, hi, lo)
        return w
    raise AssertionError(pattern)


PATTERNS = ["random", "zeros", "ones", "neg_ones", "single_row",
            "outlier_heavy"]


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t", [4, 8])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_engine_vs_ref_vs_int64(bits, t, pattern, rng):
    n, k, m = (3, 4 * t, 5) if pattern == "outlier_heavy" else (17, 6 * t, 9)
    w = _adversarial_weights(pattern, n, k, bits, rng)
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    eng = BatchedTransitiveEngine(bits=bits, t=t)
    got = eng(w, x)
    ref = transitive_gemm_ref(w, x, bits, t)
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(ref, want)


def test_outlier_heavy_actually_exercises_outliers(rng):
    """Guard the adversarial case: it must hit the direct-dispatch path."""
    w = _adversarial_weights("outlier_heavy", 3, 32, 8, rng)
    eng = BatchedTransitiveEngine(bits=8, t=8)
    plan = eng.plan(w)
    assert plan.si.outlier.sum() > 0
    assert plan.direct_tile.size > 0


@pytest.mark.parametrize("bits,t", [(4, 4), (4, 8), (8, 4), (8, 8)])
def test_engine_vs_pallas_interpret(bits, t, rng):
    """engine == Pallas kernel (interpret mode) == int64 GEMM."""
    import jax.numpy as jnp
    from repro.kernels import ops
    n, k, m = 12, 8 * t, 10
    w = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=(n, k))
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    got_eng = BatchedTransitiveEngine(bits=bits, t=t)(w, x)
    # the kernel computes qx (M, K) @ qw (N, K)^T = (engine output)^T
    got_pal = np.asarray(ops.transitive_gemm(
        jnp.asarray(x.T, jnp.int8), jnp.asarray(w, jnp.int8),
        w_bits=bits, t=t)).T
    np.testing.assert_array_equal(got_eng, want)
    np.testing.assert_array_equal(got_pal, want)


@pytest.mark.parametrize("group", [0, 64])
@pytest.mark.parametrize("w_bits", [4, 8])
def test_engine_quant_path_matches_int_dot(group, w_bits):
    """linear_apply backend="engine" is bit-exact with the int_dot one."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=w_bits, a_bits=8, group=group)
    p = linear_init(jax.random.PRNGKey(0), 128, 48, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 128), jnp.float32)
    y_int = linear_apply(p, x, cfg.with_(backend="int_dot"))
    y_eng = linear_apply(p, x, cfg.with_(backend="engine"))
    np.testing.assert_array_equal(np.asarray(y_int), np.asarray(y_eng))


# -- the registry-wide differential pyramid ---------------------------------
#
# Parametrized over list_backends() at collection time: any newly
# registered backend automatically inherits the bit-exactness obligation
# (backend == ref == int64 GEMM on the int accumulator) with no test edit.
from repro.core.backend import EngineConfig, get_backend, list_backends


@pytest.mark.parametrize("backend", list_backends())
def test_registered_backend_execute_matches_ref_and_int64(backend, rng):
    """Engine-level rung: every registered backend's execute() ==
    transitive_ref == int64 GEMM (int32 accumulator congruence)."""
    import jax.numpy as jnp
    b = get_backend(backend)
    ecfg = EngineConfig(w_bits=4, t=8, groups=1)
    w = rng.integers(-8, 8, size=(7, 32))
    x = rng.integers(-128, 128, size=(3, 32))          # row-major (M, K)
    want = x.astype(np.int64) @ w.astype(np.int64).T
    ref = transitive_gemm_ref(w, x.T, 4, 8).T
    np.testing.assert_array_equal(ref, want)
    plan = b.plan(w, ecfg) if b.needs_plan else None
    dplan = (b.compile(plan) if b.needs_plan and b.device_resident
             else None)
    got = np.asarray(b.execute(jnp.asarray(x, jnp.int8),
                               jnp.asarray(w, jnp.int8),
                               plan, dplan, ecfg))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("group", [0, 64])
@pytest.mark.parametrize("backend", list_backends())
def test_registered_backend_quant_layer_matches_int_dot(backend, group, rng):
    """Layer-level rung: linear_apply through every registered backend is
    bit-exact with int_dot — grouped and per-channel."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    b = get_backend(backend)
    if group and not b.supports_groups:
        pytest.skip(f"backend '{backend}' declares supports_groups=False")
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=group,
                      backend=backend)
    p = linear_init(jax.random.PRNGKey(0), 128, 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(linear_apply(p, x, cfg)),
        np.asarray(linear_apply(p, x, cfg.with_(backend="int_dot"))))


def test_plan_reused_across_activations(rng):
    """One plan, many activations — the paper's offline TransRow packing."""
    w = rng.integers(-8, 8, size=(9, 32))
    eng = BatchedTransitiveEngine(bits=4, t=8)
    plan = eng.plan(w)
    for seed in range(3):
        x = np.random.default_rng(seed).integers(-128, 128, size=(32, 6))
        np.testing.assert_array_equal(
            eng.run(plan, x), w.astype(np.int64) @ x.astype(np.int64))


def test_engine_rejects_bad_shapes(rng):
    eng = BatchedTransitiveEngine(bits=4, t=8)
    with pytest.raises(ValueError):
        eng.plan(rng.integers(-8, 8, size=(4, 12)))     # K % T != 0
    plan = eng.plan(rng.integers(-8, 8, size=(4, 16)))
    with pytest.raises(ValueError):
        eng.run(plan, rng.integers(-8, 8, size=(24, 3)))  # wrong K


# -- device-resident plans (compile_plan / run_device / Pallas forest) ------

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("t", [4, 8])
@pytest.mark.parametrize("pattern", PATTERNS)
def test_device_plan_vs_engine_vs_int64(bits, t, pattern, rng):
    """engine_jit pyramid rung: run_device == pallas forest == engine ==
    int64 GEMM across random and adversarial weight patterns."""
    import jax.numpy as jnp
    from repro.kernels.transitive_forest import transitive_forest
    n, k, m = (3, 4 * t, 5) if pattern == "outlier_heavy" else (11, 6 * t, 7)
    w = _adversarial_weights(pattern, n, k, bits, rng)
    x = rng.integers(-128, 128, size=(k, m))
    want = w.astype(np.int64) @ x.astype(np.int64)
    eng = BatchedTransitiveEngine(bits=bits, t=t)
    plan = eng.plan(w)
    dplan = compile_plan(plan)
    np.testing.assert_array_equal(eng.run(plan, x), want)
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(dplan, jnp.asarray(x))), want)
    np.testing.assert_array_equal(
        np.asarray(transitive_forest(dplan, jnp.asarray(x))), want)


@pytest.mark.parametrize("n_groups", [2, 4])
def test_device_plan_grouped(n_groups, rng):
    """Grouped (G>1) device plans return bit-exact per-group partials."""
    import jax.numpy as jnp
    from repro.kernels.transitive_forest import transitive_forest
    n, g, m = 6, 16, 5
    w = rng.integers(-8, 8, size=(n, n_groups * g))
    x = rng.integers(-128, 128, size=(n_groups * g, m))
    plan = BatchedTransitiveEngine(4, 8).plan(w, groups=n_groups)
    dplan = compile_plan(plan)
    want = np.einsum("ngi,gim->ngm",
                     w.reshape(n, n_groups, g).astype(np.int64),
                     x.reshape(n_groups, g, m).astype(np.int64))
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(dplan, jnp.asarray(x))), want)
    np.testing.assert_array_equal(
        np.asarray(transitive_forest(dplan, jnp.asarray(x))), want)


def test_device_plan_under_jit_vmap(rng):
    """run_device composes with jit + vmap; the jaxpr has no callback."""
    import jax
    import jax.numpy as jnp
    w = rng.integers(-8, 8, size=(9, 32))
    plan = BatchedTransitiveEngine(4, 8).plan(w)
    dplan = compile_plan(plan)
    xb = rng.integers(-128, 128, size=(3, 32, 6))
    got = np.asarray(jax.jit(jax.vmap(
        lambda xi: run_device(dplan, xi)))(jnp.asarray(xb)))
    for i in range(3):
        np.testing.assert_array_equal(
            got[i], w.astype(np.int64) @ xb[i].astype(np.int64))
    from repro import analysis
    analysis.assert_clean(
        lambda xi: run_device(dplan, xi), jnp.asarray(xb[0]),
        rules=(*analysis.DEFAULT_RULES, "gather-only-levels"),
        name="run_device")


def test_stacked_device_plans_under_scan(rng):
    """compile_plans stacks same-signature plans; lax.scan slices them —
    the layout serving uses for scan-stacked block weights."""
    import jax
    import jax.numpy as jnp
    ws = [rng.integers(-8, 8, size=(5, 32)) for _ in range(3)]
    eng = BatchedTransitiveEngine(4, 8)
    stacked = compile_plans([eng.plan(wi) for wi in ws])
    x = jnp.asarray(rng.integers(-128, 128, size=(32, 4)))

    def body(carry, dp):
        return carry, run_device(dp, x)
    _, ys = jax.jit(lambda s: jax.lax.scan(body, 0, s))(stacked)
    for i, wi in enumerate(ws):
        np.testing.assert_array_equal(
            np.asarray(ys)[i],
            wi.astype(np.int64) @ np.asarray(x).astype(np.int64))


def test_compile_plans_rejects_mixed_signatures(rng):
    eng = BatchedTransitiveEngine(4, 8)
    p1 = eng.plan(rng.integers(-8, 8, size=(5, 32)))
    p2 = eng.plan(rng.integers(-8, 8, size=(6, 32)))
    with pytest.raises(ValueError):
        compile_plans([p1, p2])
    with pytest.raises(ValueError):
        compile_plans([])


def test_run_device_rejects_bad_shapes(rng):
    dplan = compile_plan(
        BatchedTransitiveEngine(4, 8).plan(rng.integers(-8, 8, (4, 16))))
    import jax.numpy as jnp
    with pytest.raises(ValueError):
        run_device(dplan, jnp.zeros((24, 3), jnp.int32))   # wrong K


# -- plan persistence (save / load npz) -------------------------------------

@pytest.mark.parametrize("pattern", ["random", "outlier_heavy", "zeros"])
def test_plan_save_load_roundtrip(pattern, tmp_path, rng):
    """ExecutionPlan.save/load is bit-exact: every field and the executed
    output survive the npz round trip (plan persistence across processes)."""
    w = _adversarial_weights(pattern, 5, 32, 8, rng)
    eng = BatchedTransitiveEngine(bits=8, t=8)
    plan = eng.plan(w, groups=2)
    path = tmp_path / "plan.npz"
    plan.save(path)
    plan2 = ExecutionPlan.load(path)
    for f in ("t", "bits", "n", "k", "groups"):
        assert getattr(plan, f) == getattr(plan2, f)
    np.testing.assert_array_equal(plan.rows, plan2.rows)
    np.testing.assert_array_equal(plan.direct_tile, plan2.direct_tile)
    np.testing.assert_array_equal(plan.direct_bits, plan2.direct_bits)
    np.testing.assert_array_equal(plan.signs, plan2.signs)
    assert len(plan.steps) == len(plan2.steps)
    for s1, s2 in zip(plan.steps, plan2.steps):
        for f in ("tile", "node", "prefix", "bit"):
            np.testing.assert_array_equal(getattr(s1, f), getattr(s2, f))
    for f in ("counts", "exec_counts", "bridge", "distance", "prefix",
              "lane", "outlier", "wl_ppe", "wl_ape"):
        np.testing.assert_array_equal(getattr(plan.si, f),
                                      getattr(plan2.si, f))
    x = rng.integers(-128, 128, size=(32, 6))
    np.testing.assert_array_equal(eng.run(plan, x), eng.run(plan2, x))
    # the loaded plan lowers to an identical device plan
    import jax.numpy as jnp
    np.testing.assert_array_equal(
        np.asarray(run_device_jit(compile_plan(plan2), jnp.asarray(x))),
        eng.run(plan, x))


# -- quant path: engine_jit / engine_pallas ---------------------------------

@pytest.mark.parametrize("group", [0, 64])
@pytest.mark.parametrize("backend", ["engine_jit", "engine_pallas"])
def test_engine_jit_quant_path_matches_int_dot(group, backend):
    """linear_apply device backends are bit-exact with int_dot, eager and
    under jit + vmap (compared jit-to-jit: the float epilogue may fuse
    differently between jitted and eager graphs)."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=group,
                      backend=backend)
    p = linear_init(jax.random.PRNGKey(0), 128, 24, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 5, 128), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(linear_apply(p, x, cfg)),
        np.asarray(linear_apply(p, x, cfg.with_(backend="int_dot"))))

    def f(bk):
        return jax.jit(jax.vmap(
            lambda xi: linear_apply(p, xi, cfg.with_(backend=bk))))(x)
    np.testing.assert_array_equal(np.asarray(f(backend)),
                                  np.asarray(f("int_dot")))


def test_engine_jit_jaxpr_has_no_pure_callback():
    """The acceptance smoke: engine_jit lowers callback-free; the host
    engine backend (the retired hot path) still lowers *with* one."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64,
                      backend="engine_jit")
    p = linear_init(jax.random.PRNGKey(0), 128, 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 128), jnp.float32)
    from repro import analysis
    analysis.assert_clean(lambda xi: linear_apply(p, xi, cfg), x,
                          name="engine_jit-linear")
    host = analysis.find_violations(
        lambda xi: linear_apply(p, xi, cfg.with_(backend="engine")), x,
        rules=("no-host-callback",), name="engine-linear")
    assert host and all(f.rule == "no-host-callback" for f in host), host


def test_engine_jit_traced_weights_need_attached_plan():
    """Without an embedded plan, a traced weight is a loud error — not a
    silent fallback to a callback — and the error names the registry
    backends that do handle traced weights plus the attach remedy."""
    import jax
    import jax.numpy as jnp
    from repro.quant import QuantConfig, linear_init, linear_apply
    cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=0,
                      backend="engine_jit")
    p = linear_init(jax.random.PRNGKey(0), 32, 8, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32), jnp.float32)
    with pytest.raises(ValueError, match="attach_device_plans") as ei:
        jax.jit(lambda pp, xi: linear_apply(pp, xi, cfg))(p, x)
    # the remedy message lists the backends that need no attachment (the
    # fallback segment after the colon — "engine" alone would also match
    # the "backend 'engine_jit'" prefix)
    fallback = str(ei.value).rsplit("without attachment:", 1)[-1]
    for name in ("int_dot", "lut", "pallas", "engine"):
        assert name in fallback.split(".")[0].replace(" ", "").split(",")


# -- kernels/ops.py padding paths (non-divisible M/N/K) ---------------------

@pytest.mark.parametrize("m,n,k", [(13, 10, 40), (1, 3, 8), (129, 65, 264),
                                   (7, 100, 72)])
def test_ops_transitive_gemm_padding(m, n, k, rng):
    """M/N not divisible by block sizes, K not divisible by 256."""
    import jax.numpy as jnp
    from repro.kernels import ops
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    want = qx.astype(np.int64) @ qw.astype(np.int64).T
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=4, t=8))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("batch", [(2, 3), (4,)])
def test_ops_transitive_gemm_padding_batched(batch, rng):
    import jax.numpy as jnp
    from repro.kernels import ops
    k, n = 24, 11
    qx = rng.integers(-128, 128, batch + (k,)).astype(np.int8)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    want = qx.astype(np.int64) @ qw.astype(np.int64).T
    got = np.asarray(ops.transitive_gemm(jnp.asarray(qx), jnp.asarray(qw),
                                         w_bits=4, t=8))
    np.testing.assert_array_equal(got, want)


def test_ops_w4a8_gemm_padding(rng):
    """w4a8 wrapper pads M and N; K stays a group multiple."""
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    m, n, k, g = 13, 21, 128, 64
    qx = rng.integers(-128, 128, (m, k)).astype(np.int8)
    sx = rng.uniform(0.5, 2.0, (m, 1)).astype(np.float32)
    qw = rng.integers(-8, 8, (n, k)).astype(np.int8)
    sg = rng.uniform(0.5, 2.0, (n, k // g)).astype(np.float32)
    want = np.asarray(ref.w4a8_matmul_ref(*map(jnp.asarray,
                                               (qx, sx, qw, sg))))
    got = np.asarray(ops.w4a8_gemm(*map(jnp.asarray, (qx, sx, qw, sg)),
                                   group=g))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=1e-2)
