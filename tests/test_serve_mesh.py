"""The multi-device serve cell (ISSUE 5) + greedy-decode contract fixes.

Two tiers in one module:

* contract tests (any device count): the explicit ``greedy_generate``
  ``n_steps`` semantics (``n_steps=0`` returns no tokens; the old loop
  always emitted the prefill argmax), decode-step cache donation, jit
  memoisation across ``greedy_generate`` calls, the
  ``ShardingDropWarning`` on silently-replicated spec axes (including the
  multi-axis ``("pod", "data")`` product rule), and the capability-keyed
  ``plan_specs`` mesh-attach hook.
* mesh tests (skipped below 4 local devices): ``greedy_generate`` on a
  4-way ``P("data")`` mesh with attached DevicePlans is bit-identical to
  the 1-device run for ``engine_jit`` and ``engine_pallas``, decode makes
  zero PlanCache lookups, the lowered decode jaxpr stays
  ``pure_callback``-free under the mesh, and the KV caches are genuinely
  data-sharded (not silently replicated). CI runs these in a dedicated
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` leg; locally:

      XLA_FLAGS=--xla_force_host_platform_device_count=4 \
          PYTHONPATH=src python -m pytest -q tests/test_serve_mesh.py

  A slow-marked subprocess twin keeps the acceptance property reachable
  from a 1-device host via ``-m slow`` (test_distributed.py's pattern).
"""
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import analysis, jax_compat
from repro.configs import get_reduced
from repro.distributed import sharding as SH
from repro.launch.mesh import make_serve_mesh, parse_mesh_spec
from repro.launch.specs import serve_config
from repro.models.model import Model
from repro.train.serve_step import (_jit_decode_step, _jit_prefill,
                                    greedy_generate, make_decode_step)

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
NDEV = len(jax.devices())
needs_mesh = pytest.mark.skipif(
    NDEV < 4, reason="needs >= 4 devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")

MESH_BACKENDS = ("engine_jit", "engine_pallas")


@pytest.fixture
def cache():
    """Fresh process-default plan cache per test; restores the previous."""
    from repro.core.plancache import PlanCache, set_default_cache
    c = PlanCache(capacity=64)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


@pytest.fixture(scope="module")
def fp_model():
    cfg = get_reduced("smollm_135m").replace(n_layers=2, dtype=jnp.float32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8),
                                          0, cfg.vocab, jnp.int32)}
    return model, params, batch


def _quant_cell(backend: str):
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend=backend)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 8),
                                          0, cfg.vocab, jnp.int32)}
    return model, params, batch


def _data_mesh(n: int):
    return make_serve_mesh({"data": n})


# -- greedy_generate contract ------------------------------------------------

def test_n_steps_is_token_count(fp_model):
    """n_steps == tokens returned; n_steps=0 is empty, not 1 token (the
    old off-by-one); shorter runs are prefixes of longer ones (greedy)."""
    model, params, batch = fp_model
    t0 = greedy_generate(model, params, batch, max_len=32, n_steps=0)
    assert t0.shape == (2, 0) and t0.dtype == jnp.int32
    t1 = np.asarray(greedy_generate(model, params, batch, max_len=32,
                                    n_steps=1))
    t5 = np.asarray(greedy_generate(model, params, batch, max_len=32,
                                    n_steps=5))
    assert t1.shape == (2, 1) and t5.shape == (2, 5)
    np.testing.assert_array_equal(t1, t5[:, :1])
    assert (t5 >= 0).all() and (t5 < model.cfg.vocab).all()


def test_negative_n_steps_raises(fp_model):
    model, params, batch = fp_model
    with pytest.raises(ValueError, match="n_steps"):
        greedy_generate(model, params, batch, max_len=32, n_steps=-1)


def test_decode_step_donates_caches(fp_model):
    """The decode jit donates the KV caches — without donation every token
    pays a full cache-buffer copy."""
    model, params, batch = fp_model
    logits, caches = _jit_prefill(model, 32)(params, batch)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    probe = jax.tree_util.tree_leaves(caches["body"])[0]
    _, new_caches = _jit_decode_step(model, True)(params, caches, tok,
                                                  jnp.int32(8))
    assert probe.is_deleted()
    # donate=False keeps the input alive (re-enterable decode)
    probe2 = jax.tree_util.tree_leaves(new_caches["body"])[0]
    _jit_decode_step(model, False)(params, new_caches, tok, jnp.int32(9))
    assert not probe2.is_deleted()


def test_jitted_steps_memoised_across_calls(fp_model):
    """Repeated greedy_generate calls must not rebuild the jit wrappers
    (a rebuilt closure means a retrace per serving call)."""
    model, _, _ = fp_model
    assert _jit_prefill(model, 32) is _jit_prefill(model, 32)
    assert _jit_decode_step(model, True) is _jit_decode_step(model, True)
    assert _jit_decode_step(model, True) is not _jit_decode_step(model,
                                                                 False)


def test_jit_memo_keys_include_mesh(fp_model):
    """The _STEP_JITS memo keys carry the ambient mesh: a step traced
    under ``set_mesh`` bakes the mesh into its sharding constraints, but
    jit's own cache only keys on avals — interleaved mesh / no-mesh
    ``greedy_generate`` calls must get distinct jit objects, and the
    tokens must not drift across the interleaving."""
    model, params, batch = fp_model
    mesh = _data_mesh(1)
    assert _jit_prefill(model, 32) is not _jit_prefill(model, 32, mesh)
    assert _jit_prefill(model, 32, mesh) is _jit_prefill(model, 32, mesh)
    assert _jit_decode_step(model, True) is not \
        _jit_decode_step(model, True, mesh)
    assert _jit_decode_step(model, True, mesh) is \
        _jit_decode_step(model, True, mesh)
    # mesh -> no-mesh -> mesh interleaving: bit-identical throughout
    t_plain = np.asarray(greedy_generate(model, params, batch,
                                         max_len=32, n_steps=4))
    t_mesh = np.asarray(greedy_generate(model, params, batch,
                                        max_len=32, n_steps=4, mesh=mesh))
    t_plain2 = np.asarray(greedy_generate(model, params, batch,
                                          max_len=32, n_steps=4))
    np.testing.assert_array_equal(t_plain, t_mesh)
    np.testing.assert_array_equal(t_plain, t_plain2)


# -- sharding.spec non-divisibility warning ---------------------------------

class _FakeMesh:
    """Duck-typed mesh: spec(mesh=) only needs axis_names + shape."""

    def __init__(self, **shape):
        self.axis_names = tuple(shape)
        self.shape = shape


def test_spec_warns_once_on_dropped_axis():
    SH._WARNED_DROPS.clear()
    mesh = _FakeMesh(pod=2, data=2)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        # 6 % (2*2) != 0 — the multi-axis batch rule drops on the PRODUCT
        s = SH.spec("batch", None, shape=(6, 16), mesh=mesh)
        assert s == jax.sharding.PartitionSpec(None, None)
        # same drop again: deduplicated
        SH.spec("batch", None, shape=(6, 16), mesh=mesh)
    drops = [x for x in w if issubclass(x.category, SH.ShardingDropWarning)]
    assert len(drops) == 1
    msg = str(drops[0].message)
    assert "batch" in msg and "4" in msg and "6" in msg

    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        # divisible: sharded, no warning
        s = SH.spec("batch", None, shape=(8, 16), mesh=mesh)
        assert s == jax.sharding.PartitionSpec(("pod", "data"), None)
        # no shape given: caller opted out of divisibility fitting
        SH.spec("batch", None, mesh=mesh)
        # a different dropped dim is a different event — warns again
        SH.spec("batch", None, shape=(10, 16), mesh=mesh)
    assert sum(issubclass(x.category, SH.ShardingDropWarning)
               for x in w) == 1


def test_single_axis_drop_warns():
    SH._WARNED_DROPS.clear()
    mesh = _FakeMesh(model=16)
    with pytest.warns(SH.ShardingDropWarning, match="kv_heads"):
        assert SH.spec("kv_heads", shape=(8,), mesh=mesh) == \
            jax.sharding.PartitionSpec(None)


# -- capability-keyed mesh attach -------------------------------------------

def test_attach_consults_backend_plan_specs(cache):
    """attach_device_plans(mesh=) with no explicit specs asks the backend's
    plan_specs hook for the placement; explicit specs bypass it."""
    import repro.core.backend as BK
    from repro.core.plancache import attach_device_plans
    from repro.quant import QuantConfig, linear_init

    calls = []

    class Placed(BK.EngineJitBackend):
        name = "custom_placed"

        def plan_specs(self, mesh):
            calls.append(mesh)
            return jax.sharding.PartitionSpec()

    BK.register_backend(Placed())
    try:
        cfg = QuantConfig(mode="ptq", w_bits=4, a_bits=8, group=64,
                          backend="custom_placed")
        layer = linear_init(jax.random.PRNGKey(0), 128, 16, cfg)
        mesh = _data_mesh(1)
        out = attach_device_plans({"l": layer}, cfg, cache=cache, mesh=mesh)
        assert len(calls) == 1 and calls[0] is mesh
        assert "dplan" in out["l"]
        attach_device_plans({"l": layer}, cfg, cache=cache, mesh=mesh,
                            specs=jax.sharding.PartitionSpec())
        assert len(calls) == 1          # explicit specs: hook not consulted
    finally:
        BK.unregister_backend("custom_placed")


def test_parse_mesh_spec():
    assert parse_mesh_spec("data=4") == {"data": 4}
    assert parse_mesh_spec("pod=2,data=2") == {"pod": 2, "data": 2}
    for bad in ("data", "data=", "data=0", "=4", "data=4,data=2"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)
    with pytest.raises(ValueError, match="devices"):
        make_serve_mesh({"data": 10 * NDEV})


# -- the mesh serve cell (needs forced host devices) ------------------------

@needs_mesh
@pytest.mark.parametrize("backend", MESH_BACKENDS)
def test_mesh_generate_bit_identical_and_no_cache_traffic(backend, cache):
    """The acceptance property: 4-way P('data') greedy_generate with
    attached DevicePlans returns bit-identical tokens to the 1-device run,
    and decode resolves every plan from the params — zero PlanCache
    lookups (misses OR hits) after attach."""
    model, params, batch = _quant_cell(backend)
    toks1 = np.asarray(greedy_generate(
        model, model.attach_device_plans(params), batch,
        max_len=24, n_steps=5))
    mesh = _data_mesh(4)
    params_m = model.attach_device_plans(params, mesh=mesh)
    cache.reset_stats()
    toks_n = np.asarray(greedy_generate(model, params_m, batch,
                                        max_len=24, n_steps=5, mesh=mesh))
    np.testing.assert_array_equal(toks1, toks_n)
    s = cache.stats()
    assert s["misses"] == 0 and s["hits"] == 0, s


@needs_mesh
def test_mesh_matches_int_dot_reference(cache):
    """The mesh cell stays on the bit-exactness pyramid: engine_jit on the
    mesh == int_dot on one device (same quantized init)."""
    model, params, batch = _quant_cell("engine_jit")
    mesh = _data_mesh(4)
    toks_n = np.asarray(greedy_generate(
        model, model.attach_device_plans(params, mesh=mesh), batch,
        max_len=24, n_steps=5, mesh=mesh))
    ref_model = Model(model.cfg.replace(
        quant=model.cfg.quant.with_(backend="int_dot")))
    toks_ref = np.asarray(greedy_generate(ref_model, params, batch,
                                          max_len=24, n_steps=5))
    np.testing.assert_array_equal(toks_ref, toks_n)


@needs_mesh
def test_mesh_decode_jaxpr_callback_free_and_caches_sharded(cache):
    """Under the mesh the decode jaxpr has zero pure_callbacks, and the
    prefill-built KV caches are actually data-sharded (the silent-
    replication failure mode the ShardingDropWarning exists for)."""
    from repro.train.serve_step import _place_batch
    model, params, batch = _quant_cell("engine_jit")
    mesh = _data_mesh(4)
    params_m = model.attach_device_plans(params, mesh=mesh)
    with jax_compat.set_mesh(mesh):
        placed = _place_batch(batch, mesh)
        logits, caches = _jit_prefill(model, 24)(params_m, placed)
        for leaf in jax.tree_util.tree_leaves(caches["body"]):
            assert not leaf.sharding.is_fully_replicated, leaf.sharding
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
        analysis.assert_clean(make_decode_step(model), params_m, caches,
                              tok, jnp.int32(8), name="mesh-decode")


@needs_mesh
@pytest.mark.filterwarnings(
    "ignore::repro.distributed.sharding.ShardingDropWarning")
def test_mesh_engine_fast_path_bit_identical(cache):
    """The serve-engine fast path (Pallas live-page kernel decode +
    bucketed batched prefill) on a 4-way P('data') mesh is bit-identical
    to the 1-device per-request greedy_generate oracle. Bucket batch
    widths (1, 2, ...) need not divide the mesh extent — the resulting
    replication drop is expected on the prefill and silenced here."""
    from repro.serve import ServeEngine
    model, params, _ = _quant_cell("engine_jit")
    rng = np.random.default_rng(23)
    prompts = [rng.integers(1, model.cfg.vocab, size=n).tolist()
               for n in (3, 6, 9, 11)]          # ragged live-page counts
    max_len, gen = 16, 4
    p1 = model.attach_device_plans(params)
    refs = []
    for p in prompts:
        batch = {"tokens": jnp.asarray([p], jnp.int32)}
        refs.append(np.asarray(greedy_generate(
            model, p1, batch, max_len=max_len, n_steps=gen))[0])
    mesh = _data_mesh(4)
    eng = ServeEngine(model, model.attach_device_plans(params, mesh=mesh),
                      n_slots=4, max_len=max_len, page_size=4, mesh=mesh,
                      paged_kernel=True, bucket_prefill=True)
    for p in prompts:
        eng.submit(p, gen)
    done = eng.run()
    assert len(done) == len(prompts)
    assert eng.counters["prefill_batched_calls"] > 0
    assert eng.stats()["decode_traces"] == 1
    for r in done:
        np.testing.assert_array_equal(np.asarray(r.tokens), refs[r.rid],
                                      err_msg=f"rid={r.rid}")


@pytest.mark.slow
def test_mesh_serve_cell_subprocess():
    """The acceptance property from a 1-device host: the whole bit-exact
    comparison in a forced-4-device subprocess (test_distributed.py's
    pattern)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.mesh import make_serve_mesh
        from repro.launch.specs import serve_config
        from repro.models.model import Model
        from repro.train.serve_step import greedy_generate

        cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                           backend="engine_jit")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab, jnp.int32)}
        t1 = np.asarray(greedy_generate(
            model, model.attach_device_plans(params), batch,
            max_len=24, n_steps=5))
        mesh = make_serve_mesh("data=4")
        tn = np.asarray(greedy_generate(
            model, model.attach_device_plans(params, mesh=mesh), batch,
            max_len=24, n_steps=5, mesh=mesh))
        np.testing.assert_array_equal(t1, tn)
        print("MESH BIT-EXACT", mesh.devices.size)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=480)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    assert "MESH BIT-EXACT 4" in r.stdout


@needs_mesh
def test_mesh_hot_swap_bit_exact_per_generation(cache):
    """ISSUE 9 on the multi-device cell: a hot swap lands mid-flight on a
    4-way data mesh; every request bit-matches the 1-DEVICE one-shot path
    on its admitting generation's weights, and decode is traced once."""
    from repro.fleet import build_generation
    from repro.serve import ServeEngine
    model, params, _ = _quant_cell("engine_jit")
    raw1 = model.init(jax.random.PRNGKey(1234))
    mesh = _data_mesh(4)
    gen0 = build_generation(model, params, gen=0, mesh=mesh)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1, mesh=mesh)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, model.cfg.vocab, size=8).tolist()
               for _ in range(4)]
    max_len, gen_toks = 16, 4

    # 1-device references per generation (the mesh contract oracle)
    refs = {}
    for g, raw in ((0, params), (1, raw1)):
        p1 = model.attach_device_plans(raw)
        for p in prompts:
            batch = {"tokens": jnp.asarray([p], jnp.int32)}
            refs[(g, tuple(p))] = np.asarray(greedy_generate(
                model, p1, batch, max_len=max_len, n_steps=gen_toks))[0]

    eng = ServeEngine(model, gen0.params, n_slots=4, max_len=max_len,
                      page_size=4, mesh=mesh)
    with warnings.catch_warnings():
        # staggered arrivals pack < 4 rows some steps; replication is
        # bit-exact, and bit-exactness is what this test pins
        warnings.simplefilter("ignore", SH.ShardingDropWarning)
        for p in prompts[:2]:
            eng.submit(p, gen_toks)
        eng.step()                          # gen-0 requests in flight
        assert eng.swap_params(gen1.params) == 1
        submitted = 2
        while submitted < len(prompts) or eng.queue or eng.active:
            if submitted < len(prompts):
                eng.submit(prompts[submitted], gen_toks)
                submitted += 1
            eng.step()

    assert sorted({r.gen for r in eng.finished}) == [0, 1]
    for r in eng.finished:
        np.testing.assert_array_equal(
            np.asarray(r.tokens), refs[(r.gen, tuple(r.prompt))],
            err_msg=f"rid={r.rid} gen={r.gen}")
    s = eng.stats()
    assert s["decode_jit_traces"] == 1, "mesh hot swap retraced decode"
    assert eng.counters["swaps"] == 1
    assert eng.counters["generations_retired"] == 1
