"""Hypothesis compatibility shim for the property-based test modules.

When ``hypothesis`` is installed, this module re-exports the real
``given`` / ``settings`` / ``strategies`` unchanged. When it is absent
(the pinned CPU container does not ship it), a minimal fallback turns each
property test into a deterministic seeded-random sweep: ``@given(**strats)``
wraps the test in a loop of ``max_examples`` draws from per-argument
strategies, seeded from the test's qualified name so failures reproduce.

Only the strategy surface the test-suite actually uses is implemented
(``st.integers``, ``st.sampled_from``). No shrinking — the failing draw is
reported verbatim in the assertion chain instead.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class strategies:  # noqa: N801 — mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_ignored):
        """Records max_examples for the @given wrapper; other knobs no-op."""
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        """Seeded-random parametrized sweep standing in for @given."""
        def deco(fn):
            n_examples = getattr(fn, "_compat_max_examples",
                                 _DEFAULT_MAX_EXAMPLES)

            def runner():
                seed = zlib.adler32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for case in range(n_examples):
                    kwargs = {name: s.draw(rng)
                              for name, s in strats.items()}
                    try:
                        fn(**kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"falsifying example (case {case}): "
                            f"{fn.__name__}(**{kwargs!r})") from exc

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            return runner
        return deco
