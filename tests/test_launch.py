"""Launcher-layer units: collective parser, roofline terms, shape specs,
skip rules, analytic flops — all pure (no 512-device init needed)."""
import jax
import pytest

from repro.configs import ARCHS, get_config
from repro.configs.base import SHAPES
from repro.launch.roofline import (HW, collective_bytes, model_flops,
                                   roofline_terms)
from repro.launch.dryrun import DRYRUN_ARCHS, cell_skip_reason


def test_collective_parser():
    hlo = """
  %ar = f32[1024]{0} all-reduce(f32[1024]{0} %x), replica_groups={}
  %ag = bf16[16,256]{1,0} all-gather(bf16[16,16]{1,0} %y), dimensions={1}
  %rs = f32[64]{0} reduce-scatter(f32[1024]{0} %z), dimensions={0}
  %cp = s8[128]{0} collective-permute(s8[128]{0} %w)
  %no = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 2.0 * 1024 * 4          # 2x ring factor
    assert cb["all-gather"] == 16 * 256 * 2            # result bytes
    assert cb["reduce-scatter"] == 1024 * 4            # operand bytes
    assert cb["collective-permute"] == 128
    assert cb["count"] == 4
    assert cb["total"] == sum((cb["all-reduce"], cb["all-gather"],
                               cb["reduce-scatter"], cb["all-to-all"],
                               cb["collective-permute"],
                               cb["ragged-all-to-all"]))


def test_roofline_terms_dominance():
    t = roofline_terms(HW["peak_flops"], 0.0, 0.0)
    assert t["dominant"] == "compute" and t["t_compute_s"] == 1.0
    assert t["roofline_fraction"] == 1.0
    t = roofline_terms(1.0, HW["hbm_bw"], 0.0)
    assert t["dominant"] == "memory"
    t = roofline_terms(1.0, 1.0, HW["link_bw"] * 2)
    assert t["dominant"] == "collective"


@pytest.mark.parametrize("arch", DRYRUN_ARCHS)
def test_model_flops_positive_all_cells(arch):
    cfg = get_config(arch)
    for shape in SHAPES.values():
        f = model_flops(cfg, shape)
        assert f > 0
        if shape.kind == "train":
            # 6ND lower bound (attention terms only add)
            assert f >= 5.9 * 1e6 * shape.global_batch


def test_skip_rules():
    assert cell_skip_reason(get_config("qwen3_14b"),
                            SHAPES["long_500k"]) is not None
    assert cell_skip_reason(get_config("recurrentgemma_9b"),
                            SHAPES["long_500k"]) is None
    assert cell_skip_reason(get_config("xlstm_125m"),
                            SHAPES["long_500k"]) is None
    for arch in DRYRUN_ARCHS:
        assert cell_skip_reason(get_config(arch), SHAPES["train_4k"]) is None
    assert len(DRYRUN_ARCHS) == 10 and len(ARCHS) == 11


def test_effective_accum_caps_to_dp():
    from repro.launch.specs import effective_accum
    from repro.launch.mesh import make_local_mesh
    cfg = get_config("llama4_maverick_400b_a17b")     # grad_accum=16
    mesh = make_local_mesh(1, 1)
    # pretend meshes via duck shape dicts is brittle — use the real one:
    assert effective_accum(cfg, SHAPES["train_4k"], mesh) == 16
    # on a 2-wide data mesh, 256/(16*2)=8 microbatches of 16 still fit
    mesh2 = make_local_mesh(2 if jax.device_count() >= 2 else 1, 1)
    a = effective_accum(cfg, SHAPES["train_4k"], mesh2)
    assert SHAPES["train_4k"].global_batch % a == 0


def test_serve_config_flags():
    from repro.launch.specs import serve_config
    scfg = serve_config(get_config("qwen3_14b"))
    assert scfg.quant.mode == "ptq" and scfg.quant.w_bits == 4
    assert scfg.quant_attention and scfg.kv_cache_bits == 8
    w = serve_config(get_config("whisper_tiny"))
    assert not w.quant_attention and w.kv_cache_bits == 16


def test_param_specs_shapes_align():
    """Every param leaf gets a spec of matching rank (no mesh needed)."""
    from repro.distributed.sharding import param_specs
    from repro.models.model import Model
    cfg = get_config("moonshot_v1_16b_a3b").replace(n_layers=1)
    shapes = jax.eval_shape(lambda: Model(cfg).init(jax.random.PRNGKey(0)))
    specs = param_specs(shapes)
    flat_s = jax.tree_util.tree_leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or
        x.__class__.__name__ == "PartitionSpec")
    flat_p = jax.tree_util.tree_leaves(shapes)
    assert len(flat_s) == len(flat_p)
    for sp, p in zip(flat_s, flat_p):
        assert len(sp) <= p.ndim
