"""Shared test fixtures. NOTE: no XLA device-count flags here — smoke tests
and benches must see the real (single) device; multi-device tests spawn
subprocesses with their own XLA_FLAGS (tests/test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
