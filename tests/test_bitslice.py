"""Bit-slicing properties (paper Sec. 2.1-2.2): exact roundtrips."""
import numpy as np
from _compat import given, settings, strategies as st

from repro.core import bitslice

BITS = st.sampled_from([2, 4, 8])


@given(bits=BITS, n=st.integers(1, 12), k=st.integers(1, 6),
       seed=st.integers(0, 2**31 - 1))
@settings(max_examples=40, deadline=None)
def test_plane_roundtrip(bits, n, k, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=(n, k * 8))
    planes = bitslice.bit_planes(w, bits)
    assert planes.shape == (bits, n, k * 8)
    assert set(np.unique(planes)) <= {0, 1}
    back = bitslice.reconstruct_from_planes(planes, bits)
    np.testing.assert_array_equal(back, w)


@given(bits=BITS, t=st.sampled_from([4, 8]), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=30, deadline=None)
def test_transrow_pack_unpack(bits, t, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-(1 << (bits - 1)), 1 << (bits - 1), size=(9, 4 * t))
    planes = bitslice.bit_planes(w, bits)
    rows = bitslice.pack_transrows(planes, t)
    assert rows.max() < (1 << t)
    back = bitslice.unpack_transrows(rows, t)
    np.testing.assert_array_equal(back, planes)


def test_plane_signs_msb_negative():
    s = bitslice.plane_signs(8)
    assert s[-1] == -128 and s[0] == 1 and (s[:-1] > 0).all()


def test_jnp_matches_numpy(rng):
    import jax.numpy as jnp
    w = rng.integers(-8, 8, size=(5, 16))
    np_rows = bitslice.pack_transrows(bitslice.bit_planes(w, 4), 8)
    j_rows = bitslice.pack_transrows_jnp(
        bitslice.bit_planes_jnp(jnp.asarray(w), 4), 8)
    np.testing.assert_array_equal(np.asarray(j_rows), np_rows)
