"""Multi-device tests via subprocess (8 fake host devices — kept out of the
main process so other tests see 1 device)."""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env, timeout=480)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


@pytest.mark.slow
def test_sharded_train_step_runs():
    """A real (executed, not just compiled) sharded train step on a 2x4
    mesh: loss finite, params update, state donated."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import jax_compat
        from repro.configs import get_reduced
        from repro.launch.mesh import make_local_mesh
        from repro.train.train_step import (init_state, make_optimizer,
                                            make_train_step)
        from repro.optim.schedule import cosine_schedule
        from repro.data.pipeline import SyntheticLM
        from repro.models.model import Model

        cfg = get_reduced("qwen3_14b")
        mesh = make_local_mesh(2, 4)
        model, opt = Model(cfg), make_optimizer(cfg)
        with jax_compat.set_mesh(mesh):
            state = init_state(model, opt, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(model, opt,
                           cosine_schedule(1e-3, 2, 100)), donate_argnums=0)
            data = SyntheticLM(cfg, 32, 8)
            l0 = None
            for i in range(5):
                state, metrics = step(state, data.batch(i))
                if l0 is None:
                    l0 = float(metrics["loss"])
            l1 = float(metrics["loss"])
            assert np.isfinite(l0) and np.isfinite(l1)
            print("LOSSES", l0, l1)
    """)
    assert "LOSSES" in out


@pytest.mark.slow
def test_moe_ep_matches_local():
    """Expert-parallel shard_map MoE == single-device fallback (high
    capacity so nothing drops)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import jax_compat
        from repro.configs import get_reduced
        from repro.launch.mesh import make_local_mesh
        from repro.models import blocks as B

        cfg = get_reduced("moonshot_v1_16b_a3b").replace(
            expert_capacity_factor=8.0)
        p = B.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model),
                              jnp.float32) * 0.3
        y_local = np.asarray(B.apply_moe(p, x, cfg), np.float32)
        mesh = make_local_mesh(2, 4)
        with jax_compat.set_mesh(mesh):
            y_ep = np.asarray(jax.jit(
                lambda pp, xx: B.apply_moe(pp, xx, cfg))(p, x), np.float32)
        err = np.abs(y_ep - y_local).max()
        print("ERR", err)
        assert err < 5e-2, err
    """)
    assert "ERR" in out


@pytest.mark.slow
def test_compressed_pod_psum():
    """int8 error-feedback psum over the pod axis: mean error small, exact
    over repeated steps thanks to residual feedback."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro import jax_compat
        from repro.distributed.collectives import compressed_psum_tree

        mesh = jax_compat.make_mesh((2, 4), ("pod", "data"))
        g = {"a": jnp.arange(64, dtype=jnp.float32).reshape(8, 8) / 7.0}
        r = {"a": jnp.zeros((8, 8), jnp.float32)}

        def f(g, r):
            return compressed_psum_tree(g, r, "pod")

        with jax_compat.set_mesh(mesh):
            red, res = jax.jit(jax_compat.shard_map(
                f, mesh=mesh,
                in_specs=({"a": P()}, {"a": P()}),
                out_specs=({"a": P()}, {"a": P()}),
                check_vma=False))(g, r)
        want = np.asarray(g["a"])     # mean over pods of identical grads
        got = np.asarray(red["a"])
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        print("RELERR", err)
        assert err < 0.02, err
    """)
    assert "RELERR" in out


@pytest.mark.slow
def test_elastic_checkpoint_reshard():
    """Save under a 2x4 mesh, restore under 1x8 and 8-dev-less world —
    checkpoints are mesh-agnostic."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.mesh import make_local_mesh
        from repro.distributed import checkpoint as C

        d = tempfile.mkdtemp()
        mesh_a = make_local_mesh(2, 4)
        x = jnp.arange(64, dtype=jnp.float32).reshape(8, 8)
        xa = jax.device_put(x, NamedSharding(mesh_a, P("data", "model")))
        C.save(d, 1, {"x": xa})

        mesh_b = make_local_mesh(1, 8)
        sh = {"x": NamedSharding(mesh_b, P(None, "model"))}
        t = C.restore(d, 1, {"x": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                      shardings=sh)
        np.testing.assert_array_equal(np.asarray(t["x"]), np.asarray(x))
        print("ELASTIC OK", t["x"].sharding)
    """)
    assert "ELASTIC OK" in out


@pytest.mark.slow
def test_serve_decode_sharded():
    """Sharded decode step executes on a small mesh (quantized serve cfg)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import jax_compat
        from repro.configs import get_reduced
        from repro.launch.mesh import make_local_mesh
        from repro.launch.specs import serve_config
        from repro.models.model import Model

        cfg = serve_config(get_reduced("chatglm3_6b"))
        m = Model(cfg)
        mesh = make_local_mesh(2, 4)
        with jax_compat.set_mesh(mesh):
            params = m.init(jax.random.PRNGKey(0))
            batch = {"tokens": jnp.ones((4, 16), jnp.int32)}
            logits, caches = jax.jit(
                lambda p, b: m.prefill(p, b, 32))(params, batch)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
            logits2, _ = jax.jit(m.decode_step)(params, caches, tok,
                                                jnp.int32(16))
            assert np.isfinite(np.asarray(logits2)).all()
            print("DECODE OK")
    """)
    assert "DECODE OK" in out
