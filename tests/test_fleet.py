"""Live-weight serving fleet (ISSUE 9): async re-plan, atomic hot-swap,
plan-bundle distribution.

The acceptance properties pinned here:

* **hot swap is atomic and non-draining** — requests in flight when
  ``ServeEngine.swap_params`` lands finish bit-exactly on the weights
  that admitted them, requests admitted after land bit-exactly on the
  new weights (vs ``greedy_generate`` on that generation's params), and
  the decode jit is traced exactly once across the whole drill — for
  every device-resident backend in the registry;
* **rollback** — a failed replan (or a structurally-wrong swap) never
  reaches the engine: the previous generation keeps serving;
* **bundles** — a planner cell's ``write_bundles`` attaches on a fresh
  serve cell with ZERO plan builds and identical tokens; stale weights,
  config drift and byte corruption are refused (corruption even under
  ``force=True``); plus the ``ExecutionPlan.load_bundle`` validation
  matrix itself (the satellite API).
"""
import os
import subprocess
import sys
import textwrap
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced
from repro.core.backend import EngineConfig, get_backend, list_backends
from repro.core.engine import (BundleMismatchError, ExecutionPlan,
                               compile_plan, pad_device_plan)
from repro.core.plancache import (PlanCache, _canonical, set_default_cache,
                                  weight_fingerprint)
from repro.launch.specs import serve_config
from repro.models.model import Model
from repro.serve import ServeEngine
from repro.serve.engine import SwapMismatchError
from repro.fleet import (ReplanSuperseded, ReplanWorker, WeightWatcher,
                         align_device_plans, build_generation,
                         fingerprint_params, load_bundles, read_manifest,
                         write_bundles)
from repro.train.serve_step import greedy_generate

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DEVICE_BACKENDS = [n for n in list_backends()
                   if get_backend(n).device_resident
                   and get_backend(n).cpu_ok]


@pytest.fixture
def cache():
    """Fresh process-default plan cache per test; restores the previous."""
    c = PlanCache(capacity=128)
    prev = set_default_cache(c)
    yield c
    set_default_cache(prev)


@pytest.fixture(scope="module")
def jit_cell():
    """One engine_jit serve cell with TWO raw weight generations."""
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend="engine_jit")
    model = Model(cfg)
    return (cfg, model, model.init(jax.random.PRNGKey(0)),
            model.init(jax.random.PRNGKey(1234)))


def _reference(model, params, prompt, max_len, n_new):
    """The request alone through the one-shot path, same max_len."""
    batch = {"tokens": jnp.asarray([list(prompt)], jnp.int32)}
    return np.asarray(greedy_generate(model, params, batch,
                                      max_len=max_len, n_steps=n_new))[0]


def _prompts(cfg, plen=8, n=4, seed=7):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab, size=plen).tolist()
            for _ in range(n)]


def _w(seed=0, n=9, k=32):
    rng = np.random.default_rng(seed)
    return rng.integers(-8, 8, size=(n, k))


# -- ExecutionPlan.load_bundle validation (the satellite API) ----------------

def _plan_file(tmp_path, w, *, fingerprint="auto", device=True,
               name="plan.npz"):
    c = PlanCache()
    plan = c.get_or_build(w, 4, 8)
    fp = (weight_fingerprint(_canonical(w)) if fingerprint == "auto"
          else fingerprint)
    path = str(tmp_path / name)
    plan.save(path, device=compile_plan(plan) if device else None,
              backend="engine_jit" if device else None, fingerprint=fp)
    return path, plan


def test_load_bundle_roundtrip_validates_ok(tmp_path):
    w = _w(0)
    path, plan = _plan_file(tmp_path, w)
    b = ExecutionPlan.load_bundle(path, qw=w,
                                  cfg=EngineConfig(w_bits=4, t=8, groups=1))
    assert b.backend == "engine_jit" and b.device is not None
    assert b.fingerprint == weight_fingerprint(_canonical(w))
    assert (b.plan.n, b.plan.k) == (plan.n, plan.k)


def test_load_bundle_refuses_wrong_weights(tmp_path):
    path, _ = _plan_file(tmp_path, _w(0))
    w2 = _w(0)
    w2[0, 0] ^= 1                           # same shape, different bits
    with pytest.raises(BundleMismatchError, match="stale plan"):
        ExecutionPlan.load_bundle(path, qw=w2)
    # force= is the explicit escape hatch
    assert ExecutionPlan.load_bundle(path, qw=w2, force=True).plan


def test_load_bundle_refuses_wrong_config(tmp_path):
    path, _ = _plan_file(tmp_path, _w(1))
    with pytest.raises(BundleMismatchError, match="serving config"):
        ExecutionPlan.load_bundle(
            path, cfg=EngineConfig(w_bits=8, t=8, groups=1))
    assert ExecutionPlan.load_bundle(
        path, cfg=EngineConfig(w_bits=8, t=8, groups=1), force=True).plan


def test_load_bundle_shape_mismatch_raises_even_forced(tmp_path):
    path, _ = _plan_file(tmp_path, _w(2))
    with pytest.raises(BundleMismatchError, match="n, k"):
        ExecutionPlan.load_bundle(path, qw=_w(2, n=5, k=64), force=True)


def test_load_bundle_fingerprintless_cannot_validate(tmp_path):
    w = _w(3)
    path, _ = _plan_file(tmp_path, w, fingerprint=None)
    with pytest.raises(BundleMismatchError, match="no weight fingerprint"):
        ExecutionPlan.load_bundle(path, qw=w)
    assert ExecutionPlan.load_bundle(path, qw=w, force=True).plan
    # and with no validation requested, a fingerprint-less file is fine
    assert ExecutionPlan.load_bundle(path).fingerprint is None


# -- pad alignment (the no-retrace mechanism) --------------------------------

def test_pad_device_plan_is_bit_exact():
    b = get_backend("engine_jit")
    ecfg = EngineConfig(w_bits=4, t=8, groups=1)
    w = _w(4)
    plan = b.plan(w, ecfg)
    dplan = b.compile(plan)
    d = int(dplan.direct_idx.shape[-1])
    padded = pad_device_plan(dplan, d + 7)
    assert int(padded.direct_idx.shape[-1]) == d + 7
    x = np.random.default_rng(0).integers(-128, 128, size=(3, 32))
    qx, qw = jnp.asarray(x, jnp.int8), jnp.asarray(w, jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(b.execute(qx, qw, plan, dplan, ecfg)),
        np.asarray(b.execute(qx, qw, plan, padded, ecfg)))
    with pytest.raises(ValueError):
        pad_device_plan(dplan, d - 1)       # truncation is never silent
    assert pad_device_plan(dplan, d) is dplan


def test_align_device_plans_matches_avals(cache, jit_cell):
    """A later generation aligned against an earlier one lowers to the
    SAME leaf avals — the property that makes the swap retrace-free."""
    _, model, raw0, raw1 = jit_cell
    gen0 = build_generation(model, raw0, gen=0)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1)
    a0 = [(l.shape, str(l.dtype)) for l in jax.tree.leaves(gen0.params)]
    a1 = [(l.shape, str(l.dtype)) for l in jax.tree.leaves(gen1.params)]
    assert a0 == a1
    # alignment is pure padding: unaligned plans differ only in the
    # direct width, and aligning is idempotent
    assert align_device_plans(gen1.params, gen0.params) is not None
    assert fingerprint_params(gen1.params) == fingerprint_params(raw1)


# -- ReplanWorker ------------------------------------------------------------

def test_replan_worker_builds_and_notifies(cache, jit_cell):
    _, model, raw0, raw1 = jit_cell
    ready = []
    with ReplanWorker(model, on_ready=ready.append) as w:
        t = w.submit(raw1, tag="step-1")
        assert t.wait(60) and t.error is None
    g = t.generation
    assert ready == [g]
    assert g.fingerprint == fingerprint_params(raw1)
    assert g.tag == "step-1" and g.plans_built > 0
    assert w.counters["built"] == 1 and w.counters["failed"] == 0


def test_replan_worker_coalesces_and_supersedes(cache, jit_cell,
                                                monkeypatch):
    """Same-fingerprint submits share a ticket; a queued-but-unstarted
    build is superseded by newer weights (newest wins, depth-1 queue)."""
    import repro.fleet.replan as R
    _, model, raw0, raw1 = jit_cell
    gate, entered = threading.Event(), threading.Event()
    real = R.build_generation

    def gated(model, params, **kw):
        entered.set()
        assert gate.wait(timeout=60)
        return real(model, params, **kw)
    monkeypatch.setattr(R, "build_generation", gated)

    w = ReplanWorker(model)
    try:
        t0 = w.submit(raw0)
        assert entered.wait(60)             # raw0 build is parked
        assert w.submit(raw0) is t0         # in-flight coalesce
        t1 = w.submit(raw1)                 # queued
        assert w.submit(raw1) is t1         # queued coalesce
        raw2 = model.init(jax.random.PRNGKey(99))
        t2 = w.submit(raw2)                 # supersedes the queued raw1
        assert t1.done and isinstance(t1.error, ReplanSuperseded)
        gate.set()
        assert t0.wait(60) and t2.wait(60)
        assert t0.error is None and t2.error is None
        assert t2.generation.gen > t0.generation.gen
        assert w.submit(raw2) is t2         # last-completed coalesce
        assert w.counters["coalesced"] == 3
        assert w.counters["superseded"] == 1
    finally:
        gate.set()
        w.stop()


def test_replan_worker_failure_is_rollback(cache, jit_cell, monkeypatch):
    """A failed build resolves the ticket with the error and fires
    on_error — on_ready never sees it, so nothing reaches the engine."""
    import repro.fleet.replan as R
    _, model, raw0, raw1 = jit_cell
    monkeypatch.setattr(R, "build_generation",
                        lambda *a, **k: (_ for _ in ()).throw(
                            RuntimeError("scoreboard build exploded")))
    ready, errs = [], []
    with ReplanWorker(model, on_ready=ready.append,
                      on_error=errs.append) as w:
        t = w.submit(raw1)
        assert t.wait(60)
    assert isinstance(t.error, RuntimeError) and t.generation is None
    assert ready == [] and len(errs) == 1
    assert w.counters["failed"] == 1 and w.counters["built"] == 0


# -- hot swap under load -----------------------------------------------------

def _drive(eng, pending, gen_toks):
    """Submit ``pending`` one per step and run the engine dry."""
    submitted = 0
    while submitted < len(pending) or eng.queue or eng.active:
        if submitted < len(pending):
            eng.submit(pending[submitted], gen_toks)
            submitted += 1
        eng.step()


@pytest.mark.parametrize("backend", DEVICE_BACKENDS)
def test_swap_under_load_bit_exact_per_generation(cache, backend):
    """The tentpole property: a swap lands while requests are in flight;
    every request bit-matches the one-shot path on the weights of the
    generation that ADMITTED it, and decode is traced exactly once."""
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend=backend)
    model = Model(cfg)
    raw0 = model.init(jax.random.PRNGKey(0))
    raw1 = model.init(jax.random.PRNGKey(1234))
    gen0 = build_generation(model, raw0, gen=0)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1)
    plen, gen_toks, max_len = 8, 4, 16
    prompts = _prompts(cfg, plen=plen, n=4)

    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=max_len,
                      page_size=4)
    for p in prompts[:2]:
        eng.submit(p, gen_toks)
    eng.step()                              # gen-0 requests are in flight
    assert eng.swap_params(gen1.params, tag="swap") == 1
    _drive(eng, prompts[2:], gen_toks)

    gens = sorted({r.gen for r in eng.finished})
    assert gens == [0, 1], "both generations must have served requests"
    gparams = {0: gen0.params, 1: gen1.params}
    for r in eng.finished:
        want = _reference(model, gparams[r.gen], r.prompt, max_len,
                          r.max_new_tokens)
        np.testing.assert_array_equal(
            np.asarray(r.tokens), want,
            err_msg=f"rid={r.rid} gen={r.gen} ({backend})")
    s = eng.stats()
    assert s["decode_jit_traces"] == 1, "hot swap retraced decode"
    assert s["generation"] == 1 and s["in_flight_prev_gen"] == 0
    assert eng.counters["swaps"] == 1
    assert eng.counters["swap_shape_drift"] == 0
    assert eng.counters["generations_retired"] == 1


def test_swap_via_replan_worker_end_to_end(cache, jit_cell):
    """The full wiring: worker builds off-thread, on_ready stages the
    swap, the engine applies it at the next step boundary."""
    cfg, model, raw0, raw1 = jit_cell
    gen0 = build_generation(model, raw0, gen=0)
    plen, gen_toks, max_len = 8, 4, 16
    prompts = _prompts(cfg, plen=plen, n=3)
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=max_len,
                      page_size=4)
    with ReplanWorker(model, reference=gen0.params,
                      on_ready=lambda g: eng.swap_params(g.params,
                                                         tag=g.tag)) as w:
        eng.submit(prompts[0], gen_toks)
        eng.step()
        t = w.submit(raw1, tag="ckpt-1")
        # the engine keeps stepping while the build runs off-thread
        while not t.done:
            eng.step()
        assert t.error is None
        _drive(eng, prompts[1:], gen_toks)
    assert eng.generation == 1 and eng.counters["swaps"] == 1
    gparams = {0: gen0.params, 1: t.generation.params}
    for r in eng.finished:
        np.testing.assert_array_equal(
            np.asarray(r.tokens),
            _reference(model, gparams[r.gen], r.prompt, max_len,
                       r.max_new_tokens), err_msg=f"rid={r.rid}")
    assert eng.stats()["decode_jit_traces"] == 1


def test_swap_structure_mismatch_rolls_back(cache, jit_cell):
    """A structurally-wrong swap refuses up front; the engine keeps
    serving the current generation untouched."""
    cfg, model, raw0, _ = jit_cell
    gen0 = build_generation(model, raw0, gen=0)
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=16,
                      page_size=4)
    other = Model(cfg.replace(n_layers=1)).init(jax.random.PRNGKey(5))
    with pytest.raises(SwapMismatchError):
        eng.swap_params(other)
    assert eng.generation == 0 and eng.counters["swaps"] == 0
    assert eng.counters["swaps_staged"] == 0    # refused before staging
    p = _prompts(cfg, n=1)[0]
    _drive(eng, [p], 4)                     # still serving, bit-exact
    np.testing.assert_array_equal(
        np.asarray(eng.finished[0].tokens),
        _reference(model, gen0.params, p, 16, 4))


def test_superseding_swap_drops_staged_generation(cache, jit_cell):
    """Two swaps staged between the same pair of steps: only the newest
    is ever attached (the older one is superseded, never admitted to)."""
    _, model, raw0, raw1 = jit_cell
    gen0 = build_generation(model, raw0, gen=0)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1)
    raw2 = model.init(jax.random.PRNGKey(77))
    gen2 = build_generation(model, raw2, ref=gen0.params, gen=2)
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=16,
                      page_size=4)
    eng.swap_params(gen1.params, tag="a")
    final = eng.swap_params(gen2.params, tag="b")
    eng.step()
    assert eng.generation == final
    assert eng.counters["swaps_superseded"] == 1
    assert eng.counters["swaps"] == 1       # one attach, not two
    assert eng.cell.tag == "b"


# -- plan bundles ------------------------------------------------------------

def test_bundles_roundtrip_zero_builds_same_tokens(cache, jit_cell,
                                                   tmp_path):
    """Planner writes once; a fresh serve cell attaches with ZERO plan
    builds and generates identical tokens."""
    cfg, model, raw0, _ = jit_cell
    bdir = str(tmp_path / "bundles")
    manifest = write_bundles(raw0, cfg.quant, bdir)
    assert manifest["n_layers"] > 0 and manifest["n_files"] > 0
    assert read_manifest(bdir)["weights_fingerprint"] == \
        fingerprint_params(raw0)

    cell_cache = PlanCache(capacity=128)
    prev = set_default_cache(cell_cache)
    try:
        attached = load_bundles(raw0, cfg.quant, bdir)
    finally:
        set_default_cache(prev)
    assert cell_cache.stats()["misses"] == 0, \
        "the serve cell must not build plans"
    p = _prompts(cfg, n=1)[0]
    np.testing.assert_array_equal(
        _reference(model, attached, p, 16, 4),
        _reference(model, model.attach_device_plans(raw0), p, 16, 4))


def test_bundles_refuse_stale_weights_config_and_backend(cache, jit_cell,
                                                         tmp_path):
    cfg, model, raw0, raw1 = jit_cell
    bdir = str(tmp_path / "bundles")
    write_bundles(raw0, cfg.quant, bdir)
    with pytest.raises(BundleMismatchError, match="stale bundle"):
        load_bundles(raw1, cfg.quant, bdir)      # planned from raw0
    cfg8 = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                        w_bits=8, backend="engine_jit")
    with pytest.raises(BundleMismatchError):     # config or backend drift
        load_bundles(raw0, cfg8.quant, bdir)
    # force= attaches the stale bundle anyway (explicitly unsafe)
    assert load_bundles(raw1, cfg.quant, bdir, force=True) is not None


def test_bundles_corruption_refused_even_forced(cache, jit_cell, tmp_path):
    cfg, model, raw0, _ = jit_cell
    bdir = str(tmp_path / "bundles")
    manifest = write_bundles(raw0, cfg.quant, bdir)
    victim = next(iter(manifest["layers"].values()))["files"][0]["file"]
    path = os.path.join(bdir, victim)
    blob = bytearray(open(path, "rb").read())
    blob[len(blob) // 2] ^= 0xFF
    open(path, "wb").write(bytes(blob))
    # corrupted bytes are refused STRUCTURALLY (planlint) before the
    # sha256 even runs; a flip that survives parsing still dies on the
    # hash — either way, force= does not bypass damaged bytes
    from repro.analysis.planlint import PlanVerificationError
    refused = (BundleMismatchError, PlanVerificationError)
    with pytest.raises(refused, match="hash mismatch|refused|planlint"):
        load_bundles(raw0, cfg.quant, bdir)
    with pytest.raises(refused, match="hash mismatch|refused|planlint"):
        load_bundles(raw0, cfg.quant, bdir, force=True)


def test_bundles_refuse_model_shape_drift(cache, jit_cell, tmp_path):
    cfg, model, raw0, _ = jit_cell
    bdir = str(tmp_path / "bundles")
    write_bundles(raw0, cfg.quant, bdir)
    small = Model(cfg.replace(n_layers=1)).init(jax.random.PRNGKey(0))
    with pytest.raises(BundleMismatchError):
        load_bundles(small, cfg.quant, bdir, force=True)


def test_read_manifest_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError, match="manifest"):
        read_manifest(str(tmp_path / "nope"))


def test_bundles_refuse_non_device_backend(cache, tmp_path):
    cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                       backend="engine")     # host-callback, no DevicePlans
    raw = Model(cfg).init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="device plans"):
        write_bundles(raw, cfg.quant, str(tmp_path / "b"))


# -- weight watcher ----------------------------------------------------------

def test_weight_watcher_picks_up_new_checkpoints(cache, jit_cell,
                                                 tmp_path):
    from repro.distributed import checkpoint
    _, model, raw0, raw1 = jit_cell
    ckpt = str(tmp_path / "weights")
    with ReplanWorker(model) as w:
        watcher = WeightWatcher(ckpt, raw0, w)
        assert watcher.poll() is None       # empty dir: nothing to do
        checkpoint.save(ckpt, 1, raw1)
        t = watcher.poll()
        assert t is not None and t.wait(60) and t.error is None
        assert t.generation.tag == 1
        assert t.generation.fingerprint == fingerprint_params(raw1)
        assert watcher.poll() is None       # same step: seen, no resubmit


# -- the cold-process oracle (slow) ------------------------------------------

@pytest.mark.slow
def test_post_swap_matches_cold_started_process(cache, jit_cell):
    """ISSUE 9 acceptance, literally: requests admitted after the swap
    are bit-identical to a COLD-STARTED process serving the new weights
    (subprocess twin, test_serve_mesh.py's pattern)."""
    cfg, model, raw0, raw1 = jit_cell
    gen0 = build_generation(model, raw0, gen=0)
    gen1 = build_generation(model, raw1, ref=gen0.params, gen=1)
    plen, gen_toks, max_len = 8, 4, 16
    prompts = _prompts(cfg, plen=plen, n=2)
    eng = ServeEngine(model, gen0.params, n_slots=2, max_len=max_len,
                      page_size=4)
    eng.submit(prompts[0], gen_toks)
    eng.step()
    eng.swap_params(gen1.params)
    _drive(eng, prompts[1:], gen_toks)
    post = {tuple(r.prompt): r.tokens for r in eng.finished if r.gen == 1}
    assert post, "no request landed on the new generation"

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    code = textwrap.dedent(f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch.specs import serve_config
        from repro.models.model import Model
        from repro.train.serve_step import greedy_generate
        cfg = serve_config(get_reduced("smollm_135m").replace(n_layers=2),
                           backend="engine_jit")
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(1234))   # the NEW weights
        params = model.attach_device_plans(params)
        for prompt in {list(post)!r}:
            batch = {{"tokens": jnp.asarray([list(prompt)], jnp.int32)}}
            toks = np.asarray(greedy_generate(
                model, params, batch, max_len={max_len},
                n_steps={gen_toks}))[0]
            print("COLD", list(prompt), list(toks))
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=480)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    cold = {}
    for line in r.stdout.splitlines():
        if line.startswith("COLD "):
            prompt, toks = eval(line[5:].replace("] [", "]|[")
                                .split("|")[0]), \
                eval(line[5:].replace("] [", "]|[").split("|")[1])
            cold[tuple(prompt)] = toks
    assert cold.keys() == post.keys()
    for prompt, toks in post.items():
        assert list(toks) == cold[prompt], f"prompt {prompt}"
